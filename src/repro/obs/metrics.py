"""Thread-safe metrics primitives: counters, gauges, log2 histograms.

Design constraints (ARCHITECTURE.md "Observability"):

* **No per-sample allocation.**  A histogram is a fixed array of
  integer buckets keyed by the sample's binary exponent
  (``math.frexp``), plus exact running ``count``/``sum``/``min``/
  ``max``.  Percentiles are estimated by walking the cumulative bucket
  counts and reporting the geometric midpoint of the landing bucket —
  exact to within a factor of ``sqrt(2)`` by construction, which is
  plenty for latency accounting that spans six orders of magnitude.
* **Every mutation takes a lock.**  CPython's ``+=`` on an attribute is
  not atomic across preemption, and the concurrency tests assert exact
  totals under thread hammering.  The locks come from
  :mod:`repro.core.locks` at rank ``obs`` (the leaf rank), so the
  lock-order sanitizer covers metric recording performed while store or
  service locks are held.  The attribute is named ``_obs_lock`` — not
  ``_lock`` — so the static lock-graph (REPRO001) keeps the obs node
  distinct from the unranked ``_lock`` attributes elsewhere.
* **Instruments are cheap to hold.**  Call sites create instruments
  once (typically in ``__init__``) and call bound methods after; the
  disabled-mode no-op twins in :mod:`repro.obs` have the same surface.

Snapshots are plain dicts of JSON-serializable scalars; the exporter
(:mod:`repro.obs.export`) adds process metadata and the diff logic.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.locks import make_lock

# Bucket i (1-based) holds samples whose frexp exponent is
# EXP_MIN + i - 1, i.e. values in [2**(e-1), 2**e).  Bucket 0 holds
# zeros and negatives.  The range covers 2**-41 (~1e-13: nanoseconds
# are comfortably inside) through 2**40 (~1e12: terabyte-scale sizes).
EXP_MIN = -40
EXP_MAX = 40
N_BUCKETS = EXP_MAX - EXP_MIN + 2  # [zero bucket] + one per exponent


def bucket_index(value: float) -> int:
    """Bucket index of ``value`` under the fixed log2 scheme."""
    if value <= 0.0:
        return 0
    _, exp = math.frexp(value)  # value = m * 2**exp, m in [0.5, 1)
    if exp < EXP_MIN:
        exp = EXP_MIN
    elif exp > EXP_MAX:
        exp = EXP_MAX
    return exp - EXP_MIN + 1


def bucket_mid(index: int) -> float:
    """Geometric midpoint of bucket ``index`` (0 maps to 0.0)."""
    if index <= 0:
        return 0.0
    exp = index + EXP_MIN - 1
    return math.pow(2.0, exp - 0.5)


def canonical_name(name: str, labels: Dict[str, Any]) -> str:
    """``name{k=v,...}`` with sorted keys; the registry key format."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic integer counter."""

    kind = "counter"
    __slots__ = ("name", "_obs_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._obs_lock = make_lock("obs")
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._obs_lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._obs_lock:
            return self._value

    def snapshot(self) -> int:
        return self.value


class Gauge:
    """Last-value gauge; ``fn`` makes it derived (evaluated at
    snapshot time — how live compression-ratio/MB/s are exported
    without touching the hot path)."""

    kind = "gauge"
    __slots__ = ("name", "_obs_lock", "_value", "_fn")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None):
        self.name = name
        self._obs_lock = make_lock("obs")
        self._value = 0.0
        self._fn = fn

    def set(self, value: float) -> None:
        with self._obs_lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        if self._fn is not None:
            try:
                return float(self._fn())
            except (ZeroDivisionError, ValueError, TypeError):
                return 0.0
        with self._obs_lock:
            return self._value

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Fixed log2-bucket histogram with exact count/sum/min/max.

    ``observe`` is O(1) and allocation-free; percentile estimation
    happens only in ``snapshot``/``percentile``.
    """

    kind = "histogram"
    __slots__ = ("name", "_obs_lock", "_buckets", "_count", "_sum",
                 "_min", "_max")

    def __init__(self, name: str):
        self.name = name
        self._obs_lock = make_lock("obs")
        self._buckets = [0] * N_BUCKETS
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, value: float) -> None:
        idx = bucket_index(value)
        with self._obs_lock:
            self._buckets[idx] += 1
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    def _state(self) -> Tuple[List[int], int, float, float, float]:
        with self._obs_lock:
            return (list(self._buckets), self._count, self._sum,
                    self._min, self._max)

    @staticmethod
    def _percentile(buckets: List[int], count: int, lo: float, hi: float,
                    q: float) -> float:
        """Walk cumulative bucket counts to the q-th percentile and
        report the landing bucket's geometric midpoint, clamped to the
        observed [min, max]."""
        if count == 0:
            return 0.0
        target = max(1.0, math.ceil(q / 100.0 * count))
        cum = 0
        for idx, n in enumerate(buckets):
            cum += n
            if cum >= target:
                est = bucket_mid(idx)
                return min(max(est, lo), hi)
        return hi

    def percentile(self, q: float) -> float:
        buckets, count, _, lo, hi = self._state()
        return self._percentile(buckets, count, lo, hi, q)

    @property
    def count(self) -> int:
        with self._obs_lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._obs_lock:
            return self._sum

    def snapshot(self) -> Dict[str, Any]:
        buckets, count, total, lo, hi = self._state()
        if count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0,
                    "buckets": {}}
        sparse = {}
        for idx, n in enumerate(buckets):
            if n:
                key = "zero" if idx == 0 else str(idx + EXP_MIN - 1)
                sparse[key] = n
        return {
            "count": count,
            "sum": total,
            "min": lo,
            "max": hi,
            "mean": total / count,
            "p50": self._percentile(buckets, count, lo, hi, 50.0),
            "p90": self._percentile(buckets, count, lo, hi, 90.0),
            "p99": self._percentile(buckets, count, lo, hi, 99.0),
            "buckets": sparse,
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """Name -> instrument map with get-or-create semantics.

    A name may only ever be one kind (conflicts raise; the static rule
    REPRO007 catches the same mistake before runtime).  ``register``
    with ``replace=True`` supports per-instance instruments — a new
    ``TokenCache`` re-registers its owned counters so the snapshot
    follows the live instance.
    """

    def __init__(self):
        self._obs_lock = make_lock("obs")
        self._metrics: Dict[str, Any] = {}

    def _get_or_create(self, cls, name: str, labels: Dict[str, Any],
                       **kwargs) -> Any:
        key = canonical_name(name, labels)
        with self._obs_lock:
            inst = self._metrics.get(key)
            if inst is not None:
                if not isinstance(inst, cls):
                    raise ValueError(
                        f"metric {key!r} already registered as "
                        f"{inst.kind}, requested {cls.kind}")
                return inst
            inst = cls(key, **kwargs)
            self._metrics[key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None, **labels) -> Gauge:
        key = canonical_name(name, labels)
        with self._obs_lock:
            inst = self._metrics.get(key)
            if inst is not None and isinstance(inst, Gauge):
                return inst
            if inst is not None:
                raise ValueError(
                    f"metric {key!r} already registered as {inst.kind}, "
                    f"requested gauge")
            inst = Gauge(key, fn=fn)
            self._metrics[key] = inst
            return inst

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get_or_create(Histogram, name, labels)

    def register(self, inst, replace: bool = False) -> None:
        """Adopt an externally created instrument under its name."""
        with self._obs_lock:
            prior = self._metrics.get(inst.name)
            if prior is not None and not replace:
                raise ValueError(f"metric {inst.name!r} already registered")
            self._metrics[inst.name] = inst

    def names(self) -> List[str]:
        with self._obs_lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """``{"counters": {...}, "gauges": {...}, "histograms": {...}}``.

        The metric map is copied under the registry lock, then each
        instrument snapshots under its own lock — no nested obs-lock
        holds, and derived gauges run their callables lock-free.
        """
        with self._obs_lock:
            items = list(self._metrics.items())
        out: Dict[str, Dict[str, Any]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for key, inst in items:
            out[inst.kind + "s"][key] = inst.snapshot()
        return out
