"""Span tracing: bounded ring-buffer journal + timing context manager.

``obs.span("codec.compress", method="hybrid")`` is the one-liner call
sites use; it times the enclosed block, feeds a duration histogram
named ``codec.compress.s{method=hybrid}`` and appends one event to the
process journal.  The journal is a ``collections.deque(maxlen=N)``
guarded by an ``obs``-ranked lock — O(1) append, oldest events drop
first, dumpable as JSONL for offline inspection.

The disabled-mode twin (:class:`NullSpan`) still reads the clock: spans
double as the *product's* timing source (``CompactionResult.wall_s``
comes from ``span.elapsed_s``), so ``duration_s`` must stay correct
with observability off.  Cost model: two ``perf_counter`` calls per
span and nothing else — no locks, no journal, no histogram.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

from repro.core.locks import make_lock
from repro.obs.metrics import Histogram


class Journal:
    """Bounded in-memory event journal (a ring: oldest drop first)."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._obs_lock = make_lock("obs")
        self._events: deque = deque(maxlen=max(self.capacity, 1))
        self._dropped = 0

    def append(self, event: Dict[str, Any]) -> None:
        with self._obs_lock:
            if len(self._events) == self._events.maxlen:
                self._dropped += 1
            self._events.append(event)

    def events(self) -> List[Dict[str, Any]]:
        with self._obs_lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        with self._obs_lock:
            return self._dropped

    def __len__(self) -> int:
        with self._obs_lock:
            return len(self._events)

    def dump_jsonl(self, path: str) -> int:
        """Write one JSON object per line; returns the event count."""
        events = self.events()
        with open(path, "w", encoding="utf-8") as fh:
            for ev in events:
                fh.write(json.dumps(ev, sort_keys=True))
                fh.write("\n")
        return len(events)


class Span:
    """Enabled-mode span: times the block, records histogram + journal."""

    __slots__ = ("name", "labels", "_hist", "_journal", "_t0", "_wall0",
                 "duration_s")

    def __init__(self, name: str, labels: Dict[str, Any],
                 hist: Histogram, journal: Optional[Journal]):
        self.name = name
        self.labels = labels
        self._hist = hist
        self._journal = journal
        self._t0 = 0.0
        self._wall0 = 0.0
        self.duration_s = 0.0

    def __enter__(self) -> "Span":
        self._wall0 = time.time()
        self._t0 = time.perf_counter()
        return self

    @property
    def elapsed_s(self) -> float:
        """Seconds since ``__enter__`` (live, readable mid-span)."""
        return time.perf_counter() - self._t0

    def __exit__(self, exc_type, exc, tb) -> None:
        self.duration_s = time.perf_counter() - self._t0
        self._hist.observe(self.duration_s)
        if self._journal is not None:
            event = {
                "name": self.name,
                "ts": self._wall0,
                "dur_s": self.duration_s,
                "thread": threading.current_thread().name,
            }
            if self.labels:
                event["labels"] = dict(self.labels)
            if exc_type is not None:
                event["error"] = exc_type.__name__
            self._journal.append(event)


class NullSpan:
    """Disabled-mode span: clock only, records nothing.

    Not a singleton — spans carry per-use timing state — but
    construction is two attribute writes and the context protocol costs
    two ``perf_counter`` reads.
    """

    __slots__ = ("_t0", "duration_s")

    def __init__(self):
        self._t0 = 0.0
        self.duration_s = 0.0

    def __enter__(self) -> "NullSpan":
        self._t0 = time.perf_counter()
        return self

    @property
    def elapsed_s(self) -> float:
        return time.perf_counter() - self._t0

    def __exit__(self, exc_type, exc, tb) -> None:
        self.duration_s = time.perf_counter() - self._t0
