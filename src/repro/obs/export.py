"""Snapshot exporter: registry -> dict, render, and snapshot diffing.

A snapshot is a plain JSON-serializable dict::

    {"version": 1, "ts": <time.time()>,
     "counters": {name: int}, "gauges": {name: float},
     "histograms": {name: {count,sum,min,max,mean,p50,p90,p99,buckets}},
     "journal": {"len": n, "dropped": n, "capacity": n}}

Two snapshots of the same process diff into *rates*: counter deltas
divided by the wall-clock gap, histogram count/sum deltas plus the
mean within the window.  That is how the paper's throughput numbers
(MB/s) fall out of two live snapshots instead of a dedicated benchmark
run.
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional

from repro.obs.metrics import Registry
from repro.obs.trace import Journal

SNAPSHOT_VERSION = 1


def snapshot(registry: Registry,
             journal: Optional[Journal] = None) -> Dict[str, Any]:
    snap: Dict[str, Any] = {"version": SNAPSHOT_VERSION, "ts": time.time()}
    snap.update(registry.snapshot())
    if journal is not None:
        snap["journal"] = {"len": len(journal), "dropped": journal.dropped,
                           "capacity": journal.capacity}
    return snap


def _fmt(v: float) -> str:
    if isinstance(v, int):
        return str(v)
    if v == 0:
        return "0"
    if abs(v) >= 1000 or abs(v) < 0.001:
        return f"{v:.3e}"
    return f"{v:.4g}"


def render(snap: Dict[str, Any]) -> str:
    """Human-readable one-metric-per-line view of a snapshot."""
    lines = []
    for name in sorted(snap.get("counters", {})):
        lines.append(f"counter   {name} = {snap['counters'][name]}")
    for name in sorted(snap.get("gauges", {})):
        lines.append(f"gauge     {name} = {_fmt(snap['gauges'][name])}")
    for name in sorted(snap.get("histograms", {})):
        h = snap["histograms"][name]
        lines.append(
            f"histogram {name} count={h['count']} mean={_fmt(h['mean'])} "
            f"p50={_fmt(h['p50'])} p90={_fmt(h['p90'])} "
            f"p99={_fmt(h['p99'])} max={_fmt(h['max'])}")
    j = snap.get("journal")
    if j:
        lines.append(f"journal   len={j['len']} dropped={j['dropped']} "
                     f"capacity={j['capacity']}")
    return "\n".join(lines)


def diff(before: Dict[str, Any], after: Dict[str, Any]) -> Dict[str, Any]:
    """Rates between two snapshots of the same process.

    Counters report ``delta`` and ``rate_per_s``; histograms report the
    sample-count delta, its rate, and the mean value *within the
    window*; gauges report before/after.  Metrics absent from the
    earlier snapshot are treated as starting at zero.
    """
    dt = max(float(after.get("ts", 0.0)) - float(before.get("ts", 0.0)),
             1e-9)
    out: Dict[str, Any] = {"dt_s": dt, "counters": {}, "gauges": {},
                           "histograms": {}}
    for name, val in sorted(after.get("counters", {}).items()):
        delta = val - before.get("counters", {}).get(name, 0)
        out["counters"][name] = {"delta": delta, "rate_per_s": delta / dt}
    for name, val in sorted(after.get("gauges", {}).items()):
        out["gauges"][name] = {
            "before": before.get("gauges", {}).get(name, 0.0),
            "after": val}
    empty = {"count": 0, "sum": 0.0}
    for name, h in sorted(after.get("histograms", {}).items()):
        h0 = before.get("histograms", {}).get(name, empty)
        dcount = h["count"] - h0["count"]
        dsum = h["sum"] - h0["sum"]
        out["histograms"][name] = {
            "count_delta": dcount,
            "rate_per_s": dcount / dt,
            "mean_in_window": (dsum / dcount) if dcount else 0.0,
        }
    return out


def render_diff(d: Dict[str, Any]) -> str:
    lines = [f"window: {d['dt_s']:.3f}s"]
    for name, c in d["counters"].items():
        lines.append(f"counter   {name} +{c['delta']} "
                     f"({_fmt(c['rate_per_s'])}/s)")
    for name, g in d["gauges"].items():
        lines.append(f"gauge     {name} {_fmt(g['before'])} -> "
                     f"{_fmt(g['after'])}")
    for name, h in d["histograms"].items():
        lines.append(f"histogram {name} +{h['count_delta']} samples "
                     f"({_fmt(h['rate_per_s'])}/s, "
                     f"mean {_fmt(h['mean_in_window'])})")
    return "\n".join(lines)
