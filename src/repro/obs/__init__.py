"""repro.obs — unified metrics + tracing for the whole runtime.

Call-site API (the only one instrumented code should use; the static
rule REPRO007 flags direct construction of the underlying classes):

* ``obs.counter(name, **labels)`` / ``obs.gauge`` / ``obs.histogram``
  — get-or-create a shared instrument in the process-global registry.
* ``obs.derived_gauge(name, fn, **labels)`` — a gauge whose value is
  computed at snapshot time (live compression ratio, MB/s).
* ``obs.span(name, **labels)`` — context manager timing a block into a
  ``<name>.s`` histogram plus the ring-buffer journal; usable as the
  product's timing source via ``span.elapsed_s``/``span.duration_s``.
* ``obs.owned_counter(name, **labels)`` — an always-real counter owned
  by one component instance (``TokenCache`` hit/miss counts feed its
  ``stats()`` dict and must keep counting with obs disabled); it is
  *registered* into the global registry only when obs is enabled, with
  replace-on-reregister so snapshots follow the newest instance.
* ``obs.snapshot()`` / ``obs.dump_journal(path)`` — export.

Disabled mode (``REPRO_OBS=0``): the factories return shared no-op
stubs, resolved once at instrument creation — a disabled counter's
``inc`` is a single no-op method call, and nothing is registered.
``span`` still reads the clock (see :mod:`repro.obs.trace`).  The flag
is read per *factory call* — instruments are created at component
construction time, never per sample — so tests can flip the knob
between components without reimporting.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from repro.core import env
from repro.obs import export as _export
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               canonical_name)
from repro.obs.trace import Journal, NullSpan, Span

__all__ = [
    "enabled", "counter", "gauge", "derived_gauge", "histogram", "span",
    "owned_counter", "owned_gauge", "snapshot", "diff", "render",
    "render_diff",
    "dump_journal", "default_registry", "default_journal", "reset",
]


def enabled() -> bool:
    return bool(env.read("REPRO_OBS"))


class _NullCounter:
    kind = "counter"
    name = "<null>"
    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    @property
    def value(self) -> int:
        return 0


class _NullGauge:
    kind = "gauge"
    name = "<null>"
    __slots__ = ()

    def set(self, value: float) -> None:
        pass

    @property
    def value(self) -> float:
        return 0.0


class _NullHistogram:
    kind = "histogram"
    name = "<null>"
    count = 0
    sum = 0.0
    __slots__ = ()

    def observe(self, value: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return 0.0


NULL_COUNTER = _NullCounter()
NULL_GAUGE = _NullGauge()
NULL_HISTOGRAM = _NullHistogram()

_registry = Registry()
_journal: Optional[Journal] = None


def default_registry() -> Registry:
    return _registry


def default_journal() -> Journal:
    """The process journal; capacity is read from REPRO_OBS_JOURNAL at
    first use (``reset()`` re-reads it)."""
    global _journal
    if _journal is None:
        _journal = Journal(env.read("REPRO_OBS_JOURNAL"))
    return _journal


def reset() -> None:
    """Fresh registry + journal (tests); instruments already handed out
    keep working but stop appearing in snapshots."""
    global _registry, _journal
    _registry = Registry()
    _journal = None


def counter(name: str, **labels):
    if not enabled():
        return NULL_COUNTER
    return _registry.counter(name, **labels)


def gauge(name: str, **labels):
    if not enabled():
        return NULL_GAUGE
    return _registry.gauge(name, **labels)


def derived_gauge(name: str, fn: Callable[[], float], **labels):
    if not enabled():
        return NULL_GAUGE
    return _registry.gauge(name, fn=fn, **labels)


def histogram(name: str, **labels):
    if not enabled():
        return NULL_HISTOGRAM
    return _registry.histogram(name, **labels)


def owned_counter(name: str, **labels) -> Counter:
    """A real :class:`Counter` regardless of REPRO_OBS — for component
    counters whose values feed product ``stats()`` dicts.  Registered
    globally (replacing any prior instance's) only when obs is on."""
    key = canonical_name(name, labels)
    inst = Counter(key)
    if enabled():
        _registry.register(inst, replace=True)
    return inst


def owned_gauge(name: str, fn: Callable[[], float], **labels):
    """Per-instance derived gauge: unlike :func:`derived_gauge` (which
    get-or-creates, so an older instance's callable would win), this
    replaces any prior registration — snapshots follow the newest
    component instance."""
    if not enabled():
        return NULL_GAUGE
    key = canonical_name(name, labels)
    inst = Gauge(key, fn=fn)
    _registry.register(inst, replace=True)
    return inst


def span(name: str, **labels):
    if not enabled():
        return NullSpan()
    hist = _registry.histogram(name + ".s", **labels)
    return Span(name, labels, hist, default_journal())


def snapshot() -> Dict[str, Any]:
    return _export.snapshot(_registry, _journal)


def dump_journal(path: str) -> int:
    return default_journal().dump_jsonl(path)


diff = _export.diff
render = _export.render
render_diff = _export.render_diff
