"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSON results written by launch.dryrun.

    PYTHONPATH=src python -m repro.launch.report [--out EXPERIMENTS.md-section]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs.registry import ARCH_IDS, SHAPES

DEFAULT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def load_cells(result_dir: Path):
    cells = {}
    for f in sorted(result_dir.glob("*.json")):
        doc = json.loads(f.read_text())
        cells[(doc["arch"], doc["shape"], doc["mesh"])] = doc
    return cells


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(cells) -> str:
    lines = [
        "| arch | shape | mesh | status | compile s | HBM/dev GB | args GB | temp GB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                doc = cells.get((arch, shape, mesh))
                if doc is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | |")
                    continue
                st = doc["status"]
                if st != "ok":
                    tag = "SKIP" if st.startswith("skip") else "FAIL"
                    reason = st.split(":", 1)[-1][:60]
                    lines.append(f"| {arch} | {shape} | {mesh} | {tag}: {reason} | | | | |")
                    continue
                mem = doc["memory_analysis"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | {doc['compile_s']} | "
                    f"{doc['hbm_per_device_gb']:.2f} | "
                    f"{mem.get('argument_size_in_bytes', 0)/1e9:.2f} | "
                    f"{mem.get('temp_size_in_bytes', 0)/1e9:.2f} |")
    return "\n".join(lines)


def roofline_table(cells) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL_FLOPS | MF/HLO | roofline frac | dominant collectives |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            doc = cells.get((arch, shape, "single"))
            if doc is None or doc["status"] != "ok" or not doc.get("roofline"):
                if doc is not None and doc["status"].startswith("skip"):
                    lines.append(f"| {arch} | {shape} | — | — | — | N/A (skip: "
                                 f"{doc['status'].split(':',1)[-1][:40]}) | | | | |")
                continue
            rt = doc["roofline"]
            colls = sorted(doc.get("collective_bytes", {}).items(),
                           key=lambda kv: -kv[1])[:2]
            coll_s = " ".join(f"{k}:{v/1e9:.1f}GB" for k, v in colls)
            lines.append(
                f"| {arch} | {shape} | {rt['compute_s']:.4f} | {rt['memory_s']:.4f} | "
                f"{rt['collective_s']:.4f} | {rt['bottleneck']} | "
                f"{rt['model_flops']:.2e} | {rt['model_flops_ratio']:.2f} | "
                f"{rt['peak_fraction']:.2f} | {coll_s} |")
    return "\n".join(lines)


def summary(cells) -> str:
    ok = sum(1 for d in cells.values() if d["status"] == "ok")
    skip = sum(1 for d in cells.values() if d["status"].startswith("skip"))
    fail = len(cells) - ok - skip
    return (f"cells: {len(cells)} total, {ok} compiled ok, {skip} skipped "
            f"(documented N/A), {fail} failed")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(DEFAULT_DIR))
    args = ap.parse_args()
    cells = load_cells(Path(args.dir))
    print("## Dry-run summary\n")
    print(summary(cells), "\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod 16x16, per §Roofline)\n")
    print(roofline_table(cells))


if __name__ == "__main__":
    main()
