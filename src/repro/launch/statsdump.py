"""Obs snapshot dumping shared by the launchers (serve, gateway).

`write_snapshot` publishes the current ``repro.obs`` snapshot ATOMICALLY
(tmp + fsync + rename via ``core.durability.publish_durable``): a
scraper tailing ``--stats-json`` must never observe a torn JSON
document, which a plain ``open(...).write`` allows whenever the scrape
races the dump.  `start_stats_dumper` is the periodic variant — it
prints the metric *rates* since the previous dump and (optionally)
republishes the snapshot file each interval.
"""

from __future__ import annotations

import json
import threading
from typing import Optional

from repro import obs
from repro.core.durability import publish_durable


def write_snapshot(path: str, prefix: str = "") -> dict:
    """Atomically publish the current obs snapshot as JSON at ``path``.
    Returns the snapshot written."""
    snap = obs.snapshot()
    publish_durable(
        path, (json.dumps(snap, indent=1, sort_keys=True) + "\n").encode())
    if prefix:
        print(f"{prefix}obs snapshot -> {path} "
              f"({len(snap['counters'])} counters, {len(snap['gauges'])} "
              f"gauges, {len(snap['histograms'])} histograms)")
    return snap


def start_stats_dumper(interval_s: float, json_path: Optional[str] = None,
                       prefix: str = "[obs] ") -> threading.Event:
    """Print obs metric rates every ``interval_s`` seconds — and, when
    ``json_path`` is given, atomically republish the snapshot there —
    until the returned event is set (daemon thread; exits with the
    process)."""
    stop = threading.Event()

    def loop() -> None:
        prev = obs.snapshot()
        while not stop.wait(interval_s):
            cur = obs.snapshot()
            text = obs.render_diff(obs.diff(prev, cur))
            print("\n".join(prefix + line for line in text.splitlines()))
            if json_path:
                write_snapshot(json_path)
            prev = cur

    threading.Thread(target=loop, name="obs-stats-dumper",
                     daemon=True).start()
    return stop
