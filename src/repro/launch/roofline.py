"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (assignment spec):

    compute    = HLO_FLOPs      / (chips x 197e12   bf16 FLOP/s)
    memory     = HLO_bytes      / (chips x 819e9    HBM B/s)
    collective = collective_B   / (chips x 50e9     ICI B/s/link)

`cost_analysis()` provides FLOPs / bytes; collective bytes are NOT in
cost_analysis, so we parse the post-SPMD HLO text and sum the result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (result size ~= moved payload per chip for the ring
algorithms; a documented approximation).

MODEL_FLOPS uses the 6*N*D (train) / 2*N*D (inference) rule with
N = active params, so the ratio MODEL_FLOPS / HLO_FLOPs exposes
remat/dispatch/attention overheads.
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.  f32[16,512]{1,0} all-reduce(...)   or   (bf16[8,128], u32[...]) all-to-all
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the HLO module."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        if m.group(3):  # -start of a start/done pair: count once
            pass
        out[kind] = out.get(kind, 0) + _shape_bytes(shapes)
    return out


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives_by_kind: Dict[str, int]
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_ratio: float
    peak_fraction: float  # compute_s / max(all terms): roofline fraction
    memory_per_device_bytes: Optional[float] = None
    notes: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def derive_terms(*, arch: str, shape: str, mesh: str, chips: int,
                 hlo_flops: float, hlo_bytes: float,
                 collectives: Dict[str, int], model_flops: float,
                 memory_per_device: Optional[float] = None,
                 flops_are_per_chip: bool = False,
                 notes: str = "") -> RooflineTerms:
    """hlo_flops/bytes: totals from cost_analysis (global unless
    flops_are_per_chip); collective bytes are per-chip-ish result sums."""
    global_flops = hlo_flops * (chips if flops_are_per_chip else 1.0)
    global_bytes = hlo_bytes * (chips if flops_are_per_chip else 1.0)
    coll_total = float(sum(collectives.values()))
    compute_s = global_flops / chips / PEAK_FLOPS
    memory_s = global_bytes / chips / HBM_BW
    collective_s = coll_total / ICI_BW  # result sums ~ per-chip payload
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    dominant = terms[bottleneck]
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=global_flops, hlo_bytes=global_bytes,
        collective_bytes=coll_total, collectives_by_kind=collectives,
        model_flops=model_flops,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_ratio=(model_flops / global_flops if global_flops else 0.0),
        peak_fraction=(compute_s / dominant if dominant > 0 else 0.0),
        memory_per_device_bytes=memory_per_device,
        notes=notes,
    )


def model_flops_for(cfg, shape_spec, n_active: int) -> float:
    """6*N*D train, 2*N*D prefill, 2*N*B decode (one token/slot)."""
    if shape_spec.step == "train":
        return 6.0 * n_active * shape_spec.seq_len * shape_spec.global_batch
    if shape_spec.step == "prefill":
        return 2.0 * n_active * shape_spec.seq_len * shape_spec.global_batch
    return 2.0 * n_active * shape_spec.global_batch
