#!/usr/bin/env python
"""Gateway launcher: one process of the multi-process service tier.

    # the writer: owns the store lease, ingest, compaction
    PYTHONPATH=src python -m repro.launch.gateway --store-dir /data/store \
        --role writer --port 7421 --build-corpus 64

    # a standby: blocks on the lease, takes over when the writer dies
    PYTHONPATH=src python -m repro.launch.gateway --store-dir /data/store \
        --role standby --port 7422

    # read replicas: no lease, follow the writer through store.json
    PYTHONPATH=src python -m repro.launch.gateway --store-dir /data/store \
        --role replica --port 7431

Roles map straight onto `core/store.py`'s ownership model: ``writer``
opens read-write with ``lease="try"`` (fails fast if the root is owned),
``standby`` opens with ``lease="wait"`` (the takeover path — the flock
releases the instant the writer dies, even on SIGKILL), and ``replica``
opens ``readonly=True`` plus a poll thread calling ``store.refresh()``
every ``--refresh-s`` seconds so compaction swaps and new ingest become
visible without any writer→replica channel.

``--port-file`` publishes ``{"host", "port", "pid", "role"}`` (atomic
tmp+rename) once the socket is bound — how orchestration and tests
discover an ephemeral ``--port 0``.  SIGTERM drains gracefully.

Deliberately jax-free: a gateway process serves the store tier only, so
it must start in store-open time, not accelerator-runtime-import time.
"""

from __future__ import annotations

import argparse
import json
import os
import threading

from repro.core import env
from repro.core.api import PromptCompressor
from repro.core.durability import publish_durable
from repro.core.store import ShardedPromptStore
from repro.launch.statsdump import start_stats_dumper, write_snapshot
from repro.service import PromptService
from repro.service.gateway import GatewayServer
from repro.tokenizer.vocab import default_tokenizer


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store-dir", required=True,
                    help="store root shared by writer/standby/replicas")
    ap.add_argument("--role", choices=("writer", "standby", "replica"),
                    default="writer")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 binds an ephemeral port (see --port-file)")
    ap.add_argument("--port-file", default=None, metavar="PATH",
                    help="publish {host, port, pid, role} JSON at PATH "
                         "once serving (atomic tmp+rename)")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count when the writer creates a new store")
    ap.add_argument("--method", default="hybrid",
                    help="codec method for --build-corpus ingest")
    ap.add_argument("--build-corpus", type=int, default=0, metavar="N",
                    help="writer only: seed an empty store with N "
                         "synthetic prompts before serving")
    ap.add_argument("--cache-mb", type=float, default=32.0,
                    help="serve-path token cache budget in MB (0 = none)")
    ap.add_argument("--flush-batch", type=int, default=64)
    ap.add_argument("--max-pending", type=int, default=1024,
                    help="ingest queue backpressure bound (texts)")
    ap.add_argument("--compact-interval", type=float, default=0.0,
                    help="background compaction scan interval in seconds "
                         "(0 = no background compactor)")
    ap.add_argument("--scrub-interval", type=float, default=0.0,
                    help="background integrity-scrub interval in seconds "
                         "(0 = no scrubber); failing shards are "
                         "quarantined, reads degrade per key")
    ap.add_argument("--max-inflight", type=int, default=None,
                    help="admission cap (default REPRO_GATEWAY_MAX_INFLIGHT)")
    ap.add_argument("--conn-window", type=int, default=None,
                    help="per-connection window (default "
                         "REPRO_GATEWAY_CONN_WINDOW)")
    ap.add_argument("--drain-s", type=float, default=None,
                    help="SIGTERM drain budget (default "
                         "REPRO_GATEWAY_DRAIN_S)")
    ap.add_argument("--refresh-s", type=float, default=None,
                    help="replica store.json poll interval (default "
                         "REPRO_GATEWAY_REFRESH_S)")
    ap.add_argument("--lease-timeout", type=float, default=None,
                    help="standby: give up waiting for the lease after "
                         "this many seconds (default: wait forever)")
    ap.add_argument("--stats-interval", type=float, default=0.0, metavar="N",
                    help="every N seconds print obs metric rates (and "
                         "republish --stats-json)")
    ap.add_argument("--stats-json", metavar="PATH", default=None,
                    help="write the final obs snapshot to PATH (atomic)")
    args = ap.parse_args(argv)
    if args.shards < 1:
        ap.error(f"--shards ({args.shards}) must be >= 1")
    if args.build_corpus and args.role != "writer":
        ap.error("--build-corpus is writer-only: replicas and standbys "
                 "never mutate the store")
    for name in ("stats_interval", "cache_mb", "compact_interval",
                 "scrub_interval"):
        if getattr(args, name) < 0:
            ap.error(f"--{name.replace('_', '-')} must be >= 0")
    return args


def _open_store(args: argparse.Namespace) -> ShardedPromptStore:
    compressor = PromptCompressor(default_tokenizer(), method=args.method)
    if args.role == "replica":
        return ShardedPromptStore(args.store_dir, compressor, readonly=True)
    if args.role == "standby":
        print(f"[gateway] standby: waiting for the store lease on "
              f"{args.store_dir} ...", flush=True)
        return ShardedPromptStore(
            args.store_dir, compressor, n_shards=args.shards, lease="wait")
    return ShardedPromptStore(
        args.store_dir, compressor, n_shards=args.shards, lease="try")


def _seed_corpus(store: ShardedPromptStore, n: int, method: str) -> None:
    if len(store) >= n:
        return
    from repro.data.corpus import generate_corpus

    prompts = generate_corpus(n_prompts=n, seed=4)
    store.put_many([p.text for p in prompts], method)
    st = store.stats()
    print(f"[gateway] seeded store: {st['n_prompts']} prompts across "
          f"{st['n_shards']} shards, {st['space_savings_pct']:.1f}% saved",
          flush=True)


def _start_replica_refresher(store: ShardedPromptStore,
                             interval_s: float) -> threading.Event:
    stop = threading.Event()

    def loop() -> None:
        while not stop.wait(interval_s):
            try:
                store.refresh()
            except Exception as e:  # keep polling through writer churn
                print(f"[gateway] replica refresh failed (will retry): {e}",
                      flush=True)

    threading.Thread(target=loop, name="replica-refresh",
                     daemon=True).start()
    return stop


def main(argv=None) -> None:
    args = parse_args(argv)
    # leasing happens here: writer fails fast if owned, standby blocks
    # until takeover, replica never takes it
    if args.role == "standby" and args.lease_timeout is not None:
        from repro.core.lease import acquire_store_lease

        # bounded wait, then hold the refcounted lease through the
        # store's own acquisition below
        lease = acquire_store_lease(args.store_dir, mode="wait",
                                    timeout_s=args.lease_timeout)
    else:
        lease = None
    try:
        store = _open_store(args)
    except BaseException:
        if lease is not None:
            lease.release()
        raise
    readonly = args.role == "replica"
    if args.role == "standby":
        print("[gateway] standby acquired the lease: taking over as writer",
              flush=True)
    if args.build_corpus:
        _seed_corpus(store, args.build_corpus, args.method)
    service = PromptService(
        store,
        cache_bytes=int(args.cache_mb * 2 ** 20),
        ingest_async=not readonly,
        flush_batch=args.flush_batch,
        max_pending=args.max_pending,
        compact_interval_s=(args.compact_interval or None
                            if not readonly else None),
        scrub_interval_s=(args.scrub_interval or None
                          if not readonly else None),
    )
    if env.read("REPRO_FAULTS"):
        # deterministic chaos: say so in the log, loudly, so a fault spec
        # leaking into a real deployment is visible at startup
        print(f"[gateway] FAULT INJECTION ARMED: "
              f"REPRO_FAULTS={env.read('REPRO_FAULTS')!r} "
              f"seed={env.read('REPRO_FAULTS_SEED')}", flush=True)
    refresh_s = (env.read("REPRO_GATEWAY_REFRESH_S")
                 if args.refresh_s is None else args.refresh_s)
    refresher = (_start_replica_refresher(store, refresh_s)
                 if readonly else None)
    stats_stop = (start_stats_dumper(args.stats_interval,
                                     json_path=args.stats_json,
                                     prefix="[gateway][obs] ")
                  if args.stats_interval else None)
    server = GatewayServer(service, host=args.host, port=args.port,
                           max_inflight=args.max_inflight,
                           conn_window=args.conn_window,
                           drain_s=args.drain_s, readonly=readonly)

    def ready(srv: GatewayServer) -> None:
        print(f"[gateway] {args.role} serving on {args.host}:{srv.port} "
              f"(store: {len(store)} prompts, {store.n_shards} shards)",
              flush=True)
        if args.port_file:
            publish_durable(args.port_file, (json.dumps({
                "host": args.host, "port": srv.port, "pid": os.getpid(),
                "role": args.role}) + "\n").encode())

    with service:
        try:
            server.run(ready_cb=ready)
        finally:
            if refresher is not None:
                refresher.set()
            if stats_stop is not None:
                stats_stop.set()
    if args.stats_json:
        write_snapshot(args.stats_json, prefix="[gateway] ")
    store.close()
    if lease is not None:
        lease.release()
    print("[gateway] drained, exiting", flush=True)


if __name__ == "__main__":
    main()
