"""Launch layer: production mesh, multi-pod dry-run, roofline analysis,
training and serving drivers."""
