#!/usr/bin/env python
"""Production training launcher: mesh setup, sharded train step, LoPace
data pipeline, checkpoint/restart, heartbeats, straggler policy.

On this CPU container it runs the real loop on the host mesh; on a TPU
fleet the same entry point shards over the production mesh (the dry-run
proves those shardings compile for every assigned arch).

    PYTHONPATH=src python -m repro.launch.train --arch lopace --steps 100

Trains the reduced smoke config by default; pass ``--full`` (or
``--no-smoke``) for the real one.  Relaunching with the same
``--ckpt-dir`` resumes from the latest checkpoint, including the exact
`TokenPipeline` position.
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.registry import ALIASES, get_config
from repro.data.pipeline import PipelineConfig, TokenPipeline, build_store_from_corpus
from repro.dist.checkpoint import (checkpoint_extra, checkpoint_step,
                                   latest_checkpoint, restore_checkpoint,
                                   save_checkpoint)
from repro.dist.fault import FleetMonitor, Heartbeat, RestartPolicy
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import init_train_state, make_train_step


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lopace")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="train the reduced smoke config (default on; "
                         "--no-smoke or --full selects the real config)")
    ap.add_argument("--full", action="store_true",
                    help="train the full config (alias for --no-smoke)")
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default=None,
                    help="persistent checkpoint dir (required for resume "
                         "across launches; default: run-scoped temp dir)")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep-last", type=int, default=3)
    ap.add_argument("--store-dir", default=None,
                    help="PromptStore location; an already-populated store "
                         "is reopened, not rebuilt (default: temp dir)")
    ap.add_argument("--n-prompts", type=int, default=64,
                    help="corpus size when building a fresh store")
    ap.add_argument("--hb-dir", default=None,
                    help="shared heartbeat dir for fleet monitoring "
                         "(default: run-scoped temp dir)")
    ap.add_argument("--host-id", default="host0")
    args = ap.parse_args(argv)
    args.smoke = args.smoke and not args.full
    return args


_STORE_MARKER = "CORPUS_COMPLETE"
_STORE_BUILDING = "CORPUS_BUILDING"


def _reopen_store(store_dir: Path):
    from repro.core.api import PromptCompressor
    from repro.core.store import ShardedPromptStore
    from repro.tokenizer.vocab import default_tokenizer

    return ShardedPromptStore(
        store_dir, PromptCompressor(default_tokenizer(), method="hybrid"))


def _open_store(store_dir: Path, n_prompts: int):
    marker = store_dir / _STORE_MARKER
    building = store_dir / _STORE_BUILDING
    if marker.exists():  # fully built by a previous launch: reopen
        built = marker.read_text().strip()
        if built != f"n_prompts={n_prompts}":
            print(f"[launch] WARNING: reopening existing store at "
                  f"{store_dir} ({built}); --n-prompts {n_prompts} ignored "
                  f"(delete the dir to rebuild)")
        return _reopen_store(store_dir)
    if any(store_dir.glob("*.bin")):
        if building.exists():
            # OUR build died mid-ingest: training on a truncated corpus
            # would silently change the data — start over
            print(f"[launch] incomplete store at {store_dir}; rebuilding")
            import shutil

            shutil.rmtree(store_dir)
        else:
            # populated by something else (no marker of ours either way):
            # never delete data we didn't write — reopen as-is.  NOTE this
            # also catches partial builds from pre-sentinel launchers; the
            # operator decides, instead of us silently rmtree-ing.
            print(f"[launch] WARNING: reopening store at {store_dir} not "
                  f"built by this launcher; --n-prompts {n_prompts} ignored "
                  "(if this is a suspected partial build, delete the dir "
                  "to rebuild)")
            return _reopen_store(store_dir)
    store_dir.mkdir(parents=True, exist_ok=True)
    building.write_text("")  # sentinel: a *.bin without this is not ours
    store = build_store_from_corpus(store_dir, n_prompts=n_prompts, seed=0)
    marker.write_text(f"n_prompts={n_prompts}\n")
    building.unlink()
    return store


def run(args: argparse.Namespace, scratch: Path) -> None:
    if args.arch == "lopace":
        from repro.configs.lopace import CONFIG as cfg_full
    else:
        cfg_full = get_config(args.arch)
    cfg = cfg_full.smoke() if args.smoke else cfg_full
    print(f"[launch] {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"on {len(jax.devices())} device(s)")

    ckpt_dir = Path(args.ckpt_dir) if args.ckpt_dir else scratch / "ckpt"
    hb_dir = Path(args.hb_dir) if args.hb_dir else scratch / "hb"
    store_dir = Path(args.store_dir) if args.store_dir else scratch / "store"
    hb = Heartbeat(hb_dir, args.host_id)
    monitor = FleetMonitor(hb_dir)
    policy = RestartPolicy()

    store = _open_store(store_dir, args.n_prompts)
    pipe = TokenPipeline(store, PipelineConfig(
        seq_len=args.seq_len, global_batch=args.batch, seed=0))
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, remat=args.remat, grad_accum=args.grad_accum,
        compress_grads=args.compress_grads), donate_argnums=(0, 1))
    params, opt_state = init_train_state(
        jax.random.PRNGKey(0), cfg, compress_grads=args.compress_grads)

    start = 0
    ck = latest_checkpoint(ckpt_dir)
    if ck:
        state = restore_checkpoint(ck, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        pipe.restore(checkpoint_extra(ck)["data"])
        start = checkpoint_step(ck)
        print(f"[launch] resumed from step {start}")

    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        if args.grad_accum > 1:
            batch = pipe.with_accum(batch, args.grad_accum)
        params, opt_state, m = step_fn(params, opt_state, batch)
        dt = time.perf_counter() - t0
        hb.beat(step, step_time_s=dt)
        if step % 10 == 0:
            # fleet state changes on the dead_after timescale — don't
            # re-read every heartbeat file on every step
            status = monitor.scan()
            decision = policy.decide(status)
            if decision == "abort":
                raise SystemExit("[launch] too many failures; aborting")
            if decision == "restart_elastic":
                # single-host launcher: a real fleet supervisor would
                # re-carve the DP sharding here; we log and keep training
                print(f"[launch] fleet degraded (dead={status.dead}); "
                      f"continuing")
            if status.stragglers:
                print(f"[launch] stragglers: {status.stragglers} "
                      f"(median {status.median_step_time:.2f}s)")
        if (step + 1) % 10 == 0:
            print(f"step {step+1:5d} loss={float(m['loss']):.3f} "
                  f"ce={float(m['ce']):.3f} "
                  f"gnorm={float(m['grad_norm']):.2f} {dt*1e3:.0f}ms")
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            save_checkpoint(ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state},
                            extra={"data": pipe.state()},
                            keep_last=args.keep_last)
    print("[launch] done")


def main(argv=None) -> None:
    args = parse_args(argv)
    # everything not explicitly pointed at a persistent path lives in one
    # run-scoped scratch dir and is removed on exit (the old mkdtemp
    # fallbacks leaked a store + heartbeat dir per launch)
    with tempfile.TemporaryDirectory(prefix="repro_train_") as scratch:
        run(args, Path(scratch))


if __name__ == "__main__":
    main()
