#!/usr/bin/env python
"""Production training launcher: mesh setup, sharded train step, LoPace
data pipeline, checkpoint/restart, heartbeats, straggler policy.

On this CPU container it runs the real loop on the host mesh; on a TPU
fleet the same entry point shards over the production mesh (the dry-run
proves those shardings compile for every assigned arch).

    PYTHONPATH=src python -m repro.launch.train --arch lopace --steps 100
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ALIASES, get_config
from repro.data.pipeline import PipelineConfig, TokenPipeline, build_store_from_corpus
from repro.dist.checkpoint import (checkpoint_extra, checkpoint_step,
                                   latest_checkpoint, restore_checkpoint,
                                   save_checkpoint)
from repro.dist.fault import FleetMonitor, Heartbeat, RestartPolicy
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lopace")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--host-id", default="host0")
    args = ap.parse_args()

    if args.arch == "lopace":
        from repro.configs.lopace import CONFIG as cfg_full
    else:
        cfg_full = get_config(args.arch)
    cfg = cfg_full.smoke() if args.smoke else cfg_full
    print(f"[launch] {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"on {len(jax.devices())} device(s)")

    tmp = tempfile.mkdtemp(prefix="repro_train_")
    ckpt_dir = args.ckpt_dir or tmp + "/ckpt"
    hb = Heartbeat(tmp + "/hb", args.host_id)
    monitor = FleetMonitor(tmp + "/hb")
    policy = RestartPolicy()

    store = build_store_from_corpus(tmp + "/store", n_prompts=64, seed=0)
    pipe = TokenPipeline(store, PipelineConfig(
        seq_len=args.seq_len, global_batch=args.batch, seed=0))
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, remat=args.remat, grad_accum=args.grad_accum,
        compress_grads=args.compress_grads), donate_argnums=(0, 1))
    params, opt_state = init_train_state(
        jax.random.PRNGKey(0), cfg, compress_grads=args.compress_grads)

    start = 0
    ck = latest_checkpoint(ckpt_dir)
    if ck:
        state = restore_checkpoint(ck, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        pipe.restore(checkpoint_extra(ck)["data"])
        start = checkpoint_step(ck)
        print(f"[launch] resumed from step {start}")

    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        if args.grad_accum > 1:
            batch = pipe.with_accum(batch, args.grad_accum)
        params, opt_state, m = step_fn(params, opt_state, batch)
        dt = time.perf_counter() - t0
        hb.beat(step, step_time_s=dt)
        status = monitor.scan()
        if policy.decide(status) == "abort":
            raise SystemExit("[launch] too many failures; aborting")
        if (step + 1) % 10 == 0:
            print(f"step {step+1:5d} loss={float(m['loss']):.3f} "
                  f"gnorm={float(m['grad_norm']):.2f} {dt*1e3:.0f}ms")
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            save_checkpoint(ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state},
                            extra={"data": pipe.state()})
    print("[launch] done")


if __name__ == "__main__":
    main()
