"""Production mesh factory.

Single pod: 16 x 16 = 256 chips (v5e pod), axes (data, model).
Multi-pod : 2 x 16 x 16 = 512 chips, axes (pod, data, model) — the pod
axis composes with data for hierarchical gradient reduction
(reduce-scatter on ICI inside a pod, all-reduce on DCI across pods).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import; tests
and benches see the single real CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real host devices (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
