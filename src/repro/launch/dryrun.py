import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import (jax locks the device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

(No ``from __future__ import annotations`` here: the XLA_FLAGS lines above
must be the first statements in the file, which PEP 563 forbids.)

For each cell this lowers the REAL step function — `train_step` (fwd+bwd+
AdamW) for train shapes, `prefill`/`decode_step` for serving shapes — with
ShapeDtypeStruct inputs (zero allocation), the production in/out
shardings from repro.dist.sharding, and the 16x16 (single-pod) or 2x16x16
(multi-pod) mesh.  Success proves the distribution config is coherent;
`memory_analysis()` proves it fits; `cost_analysis()` + HLO collective
parsing feed the §Roofline terms.

    python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--out benchmarks/results/dryrun]
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, active_params
from repro.configs.registry import SHAPES, ShapeSpec, cells, get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (collective_bytes_from_hlo, derive_terms,
                                   model_flops_for)
from repro.models.transformer import decode_step, init_cache, init_params, prefill
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import make_train_step

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; weak-type correct, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, spec: ShapeSpec,
                grad_accum: int = 1) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.activation_dtype)
    if spec.step == "decode":
        S_in = 1
    else:
        S_in = S
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "audio_stub":
        batch["embeds"] = jax.ShapeDtypeStruct((B, S_in, cfg.d_model), act)
    elif cfg.frontend == "vision_stub" and spec.step != "decode":
        batch["tokens"] = jax.ShapeDtypeStruct((B, S_in - cfg.n_patches), i32)
        batch["patch_embeds"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), act)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S_in), i32)
    if spec.step == "train":
        batch["labels"] = jax.ShapeDtypeStruct(
            (B, S_in - (cfg.n_patches if cfg.frontend == "vision_stub" else 0)), i32)
    if grad_accum > 1:
        batch = {k: jax.ShapeDtypeStruct(
            (grad_accum, v.shape[0] // grad_accum) + v.shape[1:], v.dtype)
            for k, v in batch.items()}
    return batch


def _sds_tree(f, *args):
    return jax.eval_shape(f, *args)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def _seq_shard_specs(cfg, spec, mesh):
    """Context-parallel attention pinning for head counts that do not
    divide the model axis (musicgen 24, minicpm3 40, llava 56, rg 10):
    shard the q sequence over `model`, replicate kv (see models.attention)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    model = dict(mesh.shape).get("model", 1)
    attn_kinds = any(k in ("attn", "local", "mla") for k in cfg.block_pattern)
    if (not attn_kinds or cfg.n_heads % model == 0
            or spec.step not in ("train", "prefill")):
        return None
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return (NamedSharding(mesh, P(dp, "model", None, None)),
            NamedSharding(mesh, P(dp, None, None, None)))


def _moe_flags(cfg, spec, mesh, grad_accum):
    """(xe sharding constraint, group-chunk count) for MoE archs."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    if cfg.moe is None:
        return None, None, 1
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp_size = 1
    for a in dp:
        dp_size *= dict(mesh.shape)[a]
    xe_spec = NamedSharding(mesh, P(dp, "model", None, None))
    xg_spec = NamedSharding(mesh, P(dp, None, None))
    from repro.models.ffn import moe_groups

    B = spec.global_batch // (grad_accum if spec.step == "train" else 1)
    S = 1 if spec.step == "decode" else spec.seq_len
    G, _ = moe_groups(B * S)
    chunks = 1
    for c in (8, 4, 2):
        if G % c == 0 and (G // c) % dp_size == 0:
            chunks = c
            break
    return xe_spec, xg_spec, chunks


def _use_fsdp(cfg, spec, chips) -> bool:
    """Shard params over data too when the per-chip (model-sharded-only)
    footprint would blow HBM: params*(12B train master+moments | 2B bf16
    serve) / model_axis > 4 GB."""
    from repro.configs.base import count_params

    per_param = 12 if spec.step == "train" else 2
    model = 16
    return count_params(cfg) * per_param / model > 4e9


def _build_compiled(cfg, spec, mesh, remat, unroll, grad_accum=1):
    """Lower + compile the cell's step function for (possibly shallow) cfg."""
    from repro.models import attention as attn_mod
    from repro.models import ffn as ffn_mod

    if spec.step != "train" and cfg.param_dtype != "bfloat16":
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")  # inference wts
    attn_mod.SEQ_SHARD_SPECS = _seq_shard_specs(cfg, spec, mesh)
    from jax.sharding import NamedSharding as _NS, PartitionSpec as _P
    from repro.models import recurrent as _rec

    if "rglru" in cfg.block_pattern and spec.step in ("train", "prefill"):
        _dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        _rec.RGLRU_SEQ_SPEC = _NS(mesh, _P(_dp, "model", None))
    else:
        _rec.RGLRU_SEQ_SPEC = None
    (ffn_mod.MOE_XE_SPEC, ffn_mod.MOE_XG_SPEC,
     ffn_mod.MOE_CHUNKS) = _moe_flags(cfg, spec, mesh, grad_accum)
    rng_sds = jax.ShapeDtypeStruct((2,), "uint32")
    params_sds = _sds_tree(lambda k: init_params(k, cfg), rng_sds)
    chips = int(np.prod(list(mesh.shape.values())))
    spec_fn = shd.fsdp_pspecs if _use_fsdp(cfg, spec, chips) else shd.param_pspecs
    p_specs = shd.named(spec_fn(params_sds, cfg, mesh), mesh)
    batch_sds = input_specs(cfg, spec, grad_accum if spec.step == "train" else 1)
    b_specs = shd.named(shd.batch_pspecs(
        batch_sds, mesh, accum=(spec.step == "train" and grad_accum > 1)), mesh)

    with mesh:
        if spec.step == "train":
            opt_sds = _sds_tree(init_opt_state, params_sds)
            o_specs = shd.named(shd.zero1_pspecs(opt_sds, cfg, mesh), mesh)
            step_fn = make_train_step(cfg, AdamWConfig(), remat=remat,
                                      unroll=unroll, grad_accum=grad_accum)
            jitted = jax.jit(step_fn,
                             in_shardings=(p_specs, o_specs, b_specs),
                             out_shardings=(p_specs, o_specs, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif spec.step == "prefill":
            def prefill_fn(params, batch):
                from repro.models.transformer import forward, init_cache as ic
                B = spec.global_batch
                cache = ic(cfg, B, spec.seq_len)
                logits, cache, _ = forward(params, cfg, batch, cache=cache,
                                           unroll=unroll)
                return logits, cache

            cache_sds = _sds_tree(
                lambda: init_cache(cfg, spec.global_batch, spec.seq_len))
            c_specs = shd.named(shd.cache_pspecs(cache_sds, cfg, mesh), mesh)
            jitted = jax.jit(prefill_fn, in_shardings=(p_specs, b_specs),
                             out_shardings=(None, c_specs))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            cache_sds = _sds_tree(
                lambda: init_cache(cfg, spec.global_batch, spec.seq_len))
            c_specs = shd.named(shd.cache_pspecs(cache_sds, cfg, mesh), mesh)

            def decode_fn(params, cache, batch, pos):
                from repro.models.transformer import forward
                positions = jnp.asarray(pos, jnp.int32).reshape(1)
                logits, cache, _ = forward(params, cfg, batch, cache=cache,
                                           positions=positions, unroll=unroll)
                return logits, cache

            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(decode_fn,
                             in_shardings=(p_specs, c_specs, b_specs, None),
                             out_shardings=(None, c_specs),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, batch_sds, pos_sds)
        compiled = lowered.compile()
    return compiled


def _metrics_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    colls = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": colls}


def _slstm_correction(cfg, spec):
    """Analytic FLOPs for sLSTM recurrent matmuls beyond the scan-once
    accounting (the only sequential-scan mixer; see DESIGN.md)."""
    n_slstm = sum(1 for i in range(cfg.n_layers)
                  if cfg.kind_of_layer(i) == "slstm")
    if n_slstm == 0 or spec.step == "decode":
        return 0.0
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    steps = spec.seq_len
    per_step = 4 * spec.global_batch * nh * hd * hd * 2  # 4 gates, 2 flop/MAC
    factor = 3.0 if spec.step == "train" else 1.0        # fwd + ~2x bwd
    return (steps - 1) * per_step * factor * n_slstm


def run_cell(arch, shape_name, mesh_kind, save_hlo=False, out_dir=DEFAULT_OUT,
             remat="full", grad_accum=8, analyze=None, overrides=None,
             tag=""):
    """analyze=None -> True for the single-pod mesh only (the roofline
    table is single-pod per the assignment; multi-pod proves compilation).
    overrides: dataclasses.replace kwargs on the ModelConfig — the §Perf
    hillclimb knob (e.g. kv_cache_dtype="int8"); tag suffixes the output
    file so variants sit next to the baseline."""
    from repro.models import attention as attn_mod
    from repro.models import recurrent as rec_mod

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    if analyze is None:
        analyze = mesh_kind == "single"

    # 1) production artifact: full depth, scan-over-layers, blocked attention
    from repro.configs.base import count_params as _cp

    if spec.step == "train" and _cp(cfg) > 8e10:
        grad_accum = max(grad_accum, 16)  # 100B+ class: halve microbatch
    t0 = time.time()
    compiled = _build_compiled(cfg, spec, mesh, remat, unroll=False,
                               grad_accum=grad_accum)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    mem_doc = {a: int(getattr(mem, a)) for a in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
               if hasattr(mem, a)}
    if save_hlo:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}_{shape_name}_{mesh_kind}.hlo.txt").write_text(
            compiled.as_text())

    # 2) cost accounting: XLA counts scan/while bodies ONCE, so totals come
    # from two shallow UNROLLED lowerings (depth period+rem and 2*period+rem)
    # with full-sequence attention/chunk blocks (every internal scan -> trip
    # count 1), linearly extrapolated to the real depth:
    #   total(L) = m1 + (n_periods - 1) * (m2 - m1)
    n_per, n_rem = cfg.n_layers // cfg.period, cfg.n_layers % cfg.period
    if not analyze:
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "chips": chips, "status": "ok", "compile_s": round(t_compile, 2),
            "analysis_s": 0.0, "memory_analysis": mem_doc,
            "hbm_per_device_gb": round(
                (mem_doc.get("argument_size_in_bytes", 0)
                 + mem_doc.get("temp_size_in_bytes", 0)) / 1e9, 3),
            "roofline": None,
        }
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}_{shape_name}_{mesh_kind}.json").write_text(
            json.dumps(result, indent=1))
        return result

    from repro.models import ffn as ffn_mod

    attn_mod.ANALYSIS_FULL_BLOCKS = True
    rec_mod.ANALYSIS_FULL_CHUNKS = True
    ffn_mod.ANALYSIS_VMAP_GROUPS = True
    t0 = time.time()
    try:
        cfg1 = dataclasses.replace(cfg, n_layers=cfg.period + n_rem)
        cfg2 = dataclasses.replace(cfg, n_layers=2 * cfg.period + n_rem)
        m1 = _metrics_of(_build_compiled(cfg1, spec, mesh, "none", unroll=True))
        m2 = _metrics_of(_build_compiled(cfg2, spec, mesh, "none", unroll=True))
        if m2["flops"] < m1["flops"]:
            # nonphysical slope: the depth-1 build hit a degenerate SPMD
            # fallback (XLA "involuntary full rematerialization").  Re-anchor
            # on depths 2 and 3, whose propagation is structurally stable.
            cfg3 = dataclasses.replace(cfg, n_layers=3 * cfg.period + n_rem)
            m3 = _metrics_of(_build_compiled(cfg3, spec, mesh, "none", unroll=True))
            m1, m2 = m2, m3
            n_per -= 1  # extrapolate from the depth-2 anchor
    finally:
        attn_mod.ANALYSIS_FULL_BLOCKS = False
        rec_mod.ANALYSIS_FULL_CHUNKS = False
        ffn_mod.ANALYSIS_VMAP_GROUPS = False
        attn_mod.SEQ_SHARD_SPECS = None
        ffn_mod.MOE_XE_SPEC, ffn_mod.MOE_XG_SPEC, ffn_mod.MOE_CHUNKS = None, None, 1
    t_analysis = time.time() - t0

    def extrap(key):
        if key == "collectives":
            kinds = set(m1["collectives"]) | set(m2["collectives"])
            return {k: max(0.0, m1["collectives"].get(k, 0.0)
                           + (n_per - 1) * (m2["collectives"].get(k, 0.0)
                                            - m1["collectives"].get(k, 0.0)))
                    for k in kinds}
        return max(0.0, m1[key] + (n_per - 1) * (m2[key] - m1[key]))

    slstm_fix = _slstm_correction(cfg, spec)
    hlo_flops = extrap("flops") + slstm_fix / chips
    hlo_bytes = extrap("bytes")
    collectives = extrap("collectives")

    n_active = active_params(cfg)
    terms = derive_terms(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes, collectives=collectives,
        model_flops=model_flops_for(cfg, spec, n_active),
        memory_per_device=mem_doc.get("temp_size_in_bytes"),
        flops_are_per_chip=True,  # cost_analysis reports the per-device module
        notes=(f"depth-extrapolated from unrolled L={cfg1.n_layers},"
               f"{cfg2.n_layers}; slstm_corr={slstm_fix:.3g}"),
    )

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "status": "ok",
        "compile_s": round(t_compile, 2), "analysis_s": round(t_analysis, 2),
        "memory_analysis": mem_doc,
        "hbm_per_device_gb": round(
            (mem_doc.get("argument_size_in_bytes", 0)
             + mem_doc.get("temp_size_in_bytes", 0)) / 1e9, 3),
        "cost_extrapolated": {"flops": hlo_flops, "bytes": hlo_bytes},
        "cost_shallow": {"m1": m1, "m2": m2},
        "collective_bytes": collectives,
        "n_active_params": n_active,
        "roofline": dataclasses.asdict(terms),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}_{shape_name}_{mesh_kind}{tag}.json").write_text(
        json.dumps(result, indent=1))
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--grad-accum", type=int, default=8)
    ap.add_argument("--kv-dtype", default=None, choices=[None, "int8", "bfloat16"])
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--moe-dispatch-dtype", default=None)
    ap.add_argument("--tag", default="", help="suffix for variant outputs")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args(argv)
    out_dir = Path(args.out)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for arch, shape, status in cells(include_skipped=True):
            if status != "run":
                for mk in meshes:
                    out_dir.mkdir(parents=True, exist_ok=True)
                    (out_dir / f"{arch}_{shape}_{mk}.json").write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mk,
                         "status": status}, indent=1))
                continue
            todo += [(arch, shape, mk) for mk in meshes]
    else:
        todo = [(args.arch, args.shape, mk) for mk in meshes]

    failures = 0
    for arch, shape, mk in todo:
        tag = f"{arch} x {shape} x {mk}"
        path = out_dir / f"{arch}_{shape}_{mk}.json"
        if args.skip_existing and path.exists():
            doc = json.loads(path.read_text())
            if doc.get("status") == "ok":
                print(f"[skip] {tag}")
                continue
        try:
            overrides = {}
            if args.kv_dtype:
                overrides["kv_cache_dtype"] = args.kv_dtype
            from repro.models import ffn as _ffn
            if args.moe_group:
                _ffn.MOE_GROUP = args.moe_group
            if args.moe_dispatch_dtype:
                _ffn.MOE_DISPATCH_DTYPE = args.moe_dispatch_dtype
            r = run_cell(arch, shape, mk, save_hlo=args.save_hlo,
                         out_dir=out_dir, remat=args.remat,
                         grad_accum=args.grad_accum,
                         overrides=overrides or None, tag=args.tag)
            rt = r["roofline"]
            if rt is None:
                print(f"[ok]   {tag}: compile={r['compile_s']}s "
                      f"mem={r['hbm_per_device_gb']}GB (multi-pod: compile-proof only)")
                continue
            print(f"[ok]   {tag}: compile={r['compile_s']}s+{r['analysis_s']}s "
                  f"flops={rt['hlo_flops']:.3e} "
                  f"bottleneck={rt['bottleneck']} "
                  f"terms(c/m/x)=({rt['compute_s']:.4f},{rt['memory_s']:.4f},"
                  f"{rt['collective_s']:.4f})s")
            mem = r["memory_analysis"]
            print(f"       memory/device: args={mem.get('argument_size_in_bytes',0)/1e9:.2f}GB "
                  f"temp={mem.get('temp_size_in_bytes',0)/1e9:.2f}GB "
                  f"mfr={rt['model_flops_ratio']:.2f}")
        except Exception as e:  # record the failure — these are bugs to fix
            failures += 1
            traceback.print_exc()
            out_dir.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(
                {"arch": arch, "shape": shape, "mesh": mk,
                 "status": f"error:{type(e).__name__}",
                 "message": str(e)[:2000]}, indent=1))
            print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
