#!/usr/bin/env python
"""Serving launcher: LoPace PromptStore admission + slot-batched decode,
optionally fronted by the repro.service tier.

    PYTHONPATH=src python -m repro.launch.serve --requests 8
    PYTHONPATH=src python -m repro.launch.serve --cache-mb 32 --compact \
        --ingest-async

`--cache-mb` admits prompts through the serve-path token cache,
`--ingest-async` builds the corpus store through the async ingest queue,
`--compact` runs a stage-reselecting compaction pass before serving
(`--train-dict` lets it train and adopt per-shard dictionaries), and
`--rebalance N` re-partitions the store across N shards online first.
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax

from repro.data.pipeline import build_store_from_corpus
from repro.launch.statsdump import start_stats_dumper, write_snapshot
from repro.train.serve_loop import BatchServer
from repro.train.train_loop import init_train_state


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--shards", type=int, default=4,
                    help="PromptStore segment count (group-commit batch writes)")
    ap.add_argument("--cache-mb", type=float, default=0.0,
                    help="serve-path token cache budget in MB (0 = no cache)")
    ap.add_argument("--ingest-async", action="store_true",
                    help="ingest the corpus through the async ingest queue "
                         "(per-shard parallel group commits)")
    ap.add_argument("--compact", action="store_true",
                    help="run a stage-reselecting compaction pass over every "
                         "shard before serving")
    ap.add_argument("--train-dict", action="store_true",
                    help="let the compaction pass train per-shard "
                         "dictionaries and adopt them on a strict "
                         "total-bytes win (implies --compact)")
    ap.add_argument("--rebalance", type=int, default=0, metavar="N",
                    help="re-partition the store across N shards online "
                         "before serving (0 = keep the built layout)")
    ap.add_argument("--smoke", action="store_true",
                    help="quick mode: small request/slot/decode budgets, "
                         "async ingest and a token cache on — exercises "
                         "every instrumented path in a few seconds")
    ap.add_argument("--stats-interval", type=float, default=0.0, metavar="N",
                    help="every N seconds print the obs metric rates since "
                         "the previous dump (0 = off)")
    ap.add_argument("--stats-json", metavar="PATH", default=None,
                    help="write the final repro.obs snapshot to PATH as JSON")
    args = ap.parse_args(argv)
    if args.rebalance < 0:
        ap.error(f"--rebalance ({args.rebalance}) must be >= 0")
    if args.stats_interval < 0:
        ap.error(f"--stats-interval ({args.stats_interval}) must be >= 0")
    if args.smoke:
        args.requests = min(args.requests, 4)
        args.slots = min(args.slots, 2)
        args.max_new = min(args.max_new, 8)
        args.ingest_async = True
        if args.cache_mb == 0.0:
            args.cache_mb = 8.0
    # an oversized --max-new would otherwise silently truncate the prompt
    # to an empty or negative slice in BatchServer._fill_slots
    # (prompt_tokens[:max_len - max_new - 1]) — refuse at parse time;
    # max_len - 2 is the largest budget leaving >= 1 prompt token
    if args.max_new > args.max_len - 2:
        ap.error(f"--max-new ({args.max_new}) must be <= --max-len - 2 "
                 f"({args.max_len - 2}): the decode budget has to leave "
                 "room for at least one prompt token")
    return args


def main(argv=None) -> None:
    args = parse_args(argv)

    from repro.configs.lopace import CONFIG
    from repro.service import PromptService

    cfg = CONFIG.smoke()
    stats_stop = (start_stats_dumper(args.stats_interval,
                                     json_path=args.stats_json,
                                     prefix="[obs] ")
                  if args.stats_interval else None)
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as tmp:
        store = build_store_from_corpus(tmp, n_prompts=max(8, args.requests), seed=4,
                                        n_shards=args.shards,
                                        async_ingest=args.ingest_async)
        st = store.stats()
        print(f"[serve] store: {st['n_prompts']} prompts across "
              f"{st['n_shards']} shards, {st['space_savings_pct']:.1f}% saved"
              + (" (async ingest)" if args.ingest_async else ""))
        service = PromptService(store, cache_bytes=int(args.cache_mb * 2 ** 20),
                                ingest_async=False)
        with service:
            if args.rebalance:
                res = service.rebalance(args.rebalance)
                print(f"[serve] rebalanced {res['n_shards_before']} -> "
                      f"{res['n_shards_after']} shards "
                      f"({res['n_records']} records, {res['wall_s']:.2f}s)")
            if args.compact or args.train_dict:
                for res in service.compact(train_dict=args.train_dict):
                    print(f"[serve] compacted shard {res.shard_id}: "
                          f"{res.bytes_before} -> {res.bytes_after} B"
                          + (f" (re-encoded {res.method}"
                             + (f", dict {res.dict_bytes} B" if res.used_dict
                                else "") + ")" if res.reencoded else ""))
            server = BatchServer(params, cfg, batch_slots=args.slots,
                                 max_len=args.max_len)
            keys = service.keys()[: args.requests]
            if args.smoke and service.cache is not None:
                # warm pass: the admission below then serves from the
                # token cache, the hot-prompt path of a production tier
                service.get_tokens_many(keys)
            # admission goes through the service: cache hits skip the
            # codec decode on repeat keys
            t0 = time.perf_counter()
            reqs = server.submit_text_many(service, keys,
                                           max_new_tokens=args.max_new)
            server.run()
            dt = time.perf_counter() - t0
            toks = sum(len(r.out_tokens) for r in reqs)
            print(f"[serve] {sum(r.done for r in reqs)}/{len(reqs)} requests, "
                  f"{toks} tokens in {dt:.1f}s ({toks/dt:.1f} tok/s)")
            if service.cache is not None:
                cs = service.cache.stats()
                print(f"[serve] token cache: {cs['hits']} hits / "
                      f"{cs['misses']} misses, {cs['bytes']} B cached")
    if stats_stop is not None:
        stats_stop.set()
    if args.stats_json:
        # atomic tmp+rename publish: a scraper tailing the file can never
        # observe a torn JSON document
        write_snapshot(args.stats_json, prefix="[serve] ")


if __name__ == "__main__":
    main()
