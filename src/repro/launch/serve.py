#!/usr/bin/env python
"""Serving launcher: LoPace PromptStore admission + slot-batched decode.

    PYTHONPATH=src python -m repro.launch.serve --requests 8
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax

from repro.data.pipeline import build_store_from_corpus
from repro.train.serve_loop import BatchServer
from repro.train.train_loop import init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    from repro.configs.lopace import CONFIG

    cfg = CONFIG.smoke()
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as tmp:
        store = build_store_from_corpus(tmp, n_prompts=max(8, args.requests), seed=4)
        server = BatchServer(params, cfg, batch_slots=args.slots,
                             max_len=args.max_len)
        keys = store.keys()[: args.requests]
        t0 = time.perf_counter()
        reqs = [server.submit_text(store, k, max_new_tokens=args.max_new)
                for k in keys]
        server.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in reqs)
        print(f"[serve] {sum(r.done for r in reqs)}/{len(reqs)} requests, "
              f"{toks} tokens in {dt:.1f}s ({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
