#!/usr/bin/env python
"""Serving launcher: LoPace PromptStore admission + slot-batched decode.

    PYTHONPATH=src python -m repro.launch.serve --requests 8
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax

from repro.data.pipeline import build_store_from_corpus
from repro.train.serve_loop import BatchServer
from repro.train.train_loop import init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--shards", type=int, default=4,
                    help="PromptStore segment count (group-commit batch writes)")
    args = ap.parse_args()

    from repro.configs.lopace import CONFIG

    cfg = CONFIG.smoke()
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as tmp:
        store = build_store_from_corpus(tmp, n_prompts=max(8, args.requests), seed=4,
                                        n_shards=args.shards)
        st = store.stats()
        print(f"[serve] store: {st['n_prompts']} prompts across "
              f"{st['n_shards']} shards, {st['space_savings_pct']:.1f}% saved")
        server = BatchServer(params, cfg, batch_slots=args.slots,
                             max_len=args.max_len)
        keys = store.keys()[: args.requests]
        t0 = time.perf_counter()
        reqs = server.submit_text_many(store, keys, max_new_tokens=args.max_new)
        server.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in reqs)
        print(f"[serve] {sum(r.done for r in reqs)}/{len(reqs)} requests, "
              f"{toks} tokens in {dt:.1f}s ({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
