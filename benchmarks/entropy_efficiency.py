"""Paper §3.6: Shannon efficiency eta = CR_actual / CR_theoretical.
(Paper band 60-80% for order-0; LZ exceeds 1.0 on repetitive text —
reported per content kind.)"""

import numpy as np

from benchmarks.common import corpus, csv_row
from repro.core.api import compress_hybrid
from repro.core.entropy import efficiency, shannon_entropy
from repro.tokenizer.vocab import default_tokenizer


def run() -> list:
    tok = default_tokenizer()
    by_kind = {}
    for p in corpus(96):
        blob = compress_hybrid(p.text, tok, level=15)
        by_kind.setdefault(p.kind, []).append(
            (shannon_entropy(p.text), efficiency(p.text, len(blob))))
    rows = []
    for kind, vals in sorted(by_kind.items()):
        h = np.mean([v[0] for v in vals])
        eta = np.mean([v[1] for v in vals])
        rows.append(csv_row(f"eta_{kind}", 0,
                            f"H={h:.2f}bits/char eta={100*eta:.0f}%"))
    return rows
