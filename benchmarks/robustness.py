"""Paper Tables 2-3 / §5.10: SHA-256 integrity sweep across a diverse
prompt collection bucketed by size (paper: 27,978 cycles, 100% success)."""

import os

from benchmarks.common import METHODS, all_cycles, csv_row, corpus, run_cycle
from repro.core.api import PromptCompressor
from repro.tokenizer.vocab import default_tokenizer

N_EXTRA = int(os.environ.get("REPRO_BENCH_ROBUST", "200"))

_EDGE_CASES = [
    "", " ", "\n", "\x00ab\x01", "a", "🎉" * 50, "ñ" * 1000,
    '{"deeply": {"nested": {"json": [1, 2, {"x": null}]}}}' * 40,
    "<|system|>" * 30, "\t\r\n" * 200, "0" * 65536,
    "".join(chr(i) for i in range(32, 0x2000, 7)),
]


def run() -> list:
    pc = PromptCompressor(default_tokenizer(), level=15)
    cases = [p.text for p in corpus()] + _EDGE_CASES
    cases += [p.text for p in __import__("repro.data.corpus", fromlist=["generate_corpus"])
              .generate_corpus(N_EXTRA, seed=999)]
    buckets = {"0-1KB": [0, 0], "1-10KB": [0, 0], "10-100KB": [0, 0],
               ">100KB": [0, 0]}
    ok = fail = 0
    for text in cases:
        nb = len(text.encode())
        bucket = ("0-1KB" if nb < 1024 else "1-10KB" if nb < 10240
                  else "10-100KB" if nb < 102400 else ">100KB")
        for m in METHODS:
            c = run_cycle(pc, text, m, track_memory=False)
            if c.lossless:
                ok += 1
                buckets[bucket][0] += 1
            else:
                fail += 1
                buckets[bucket][1] += 1
    rows = [csv_row("table2_robustness_total", 0,
                    f"cycles={ok+fail} success={ok} failure={fail} "
                    f"sha256_match={100.0*ok/(ok+fail):.1f}%")]
    for b, (s, f) in buckets.items():
        if s + f:
            rows.append(csv_row(f"table3_bucket_{b}", 0,
                                f"success={s} failure={f} rate={100.0*s/(s+f):.1f}%"))
    return rows
