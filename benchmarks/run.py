"""Benchmark driver (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows; exit code 0 iff every
lossless check passed.  Rows whose derived field starts with ``SKIP``
(e.g. the service benchmarks on a read-only store root) count as
passed."""

import importlib
import sys
import time

# modules whose absence downgrades a benchmark to a SKIP row instead of
# failing the sweep (requirements-dev.txt; not baked into every container)
_OPTIONAL_DEPS = ("zstandard", "hypothesis")

MODULES = [
    ("table5_compression_ratio", "compression_ratio"),
    ("table6_space_savings", "space_savings"),
    ("table7_throughput", "throughput"),
    ("sec5.5_memory", "memory"),
    ("table2_3_robustness", "robustness"),
    ("sec5.7_scaling", "scaling"),
    ("sec3.6_entropy", "entropy_efficiency"),
    ("sec5.3_disk", "disk_sizes"),
    ("beyond_paper_baselines", "baselines"),
    ("store_batch_throughput", "batch_throughput"),
    ("service_throughput", "service_throughput"),
    ("gateway_throughput", "gateway_throughput"),
    ("dist_grad_compress", "grad_compress"),
    ("codec_throughput", "codec_throughput"),
    ("kernel_codec", "kernel_throughput"),
    ("obs_overhead", "obs_overhead"),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = False
    for name, modname in MODULES:
        t0 = time.perf_counter()
        try:
            # import inside the loop so a benchmark that imports an
            # optional dependency at module level SKIPs instead of
            # killing the whole sweep before it starts
            rows = importlib.import_module(f"benchmarks.{modname}").run()
        except ImportError as e:
            if e.name in _OPTIONAL_DEPS:
                rows = [f"{name},0,SKIP:missing_dependency:{e.name}"]
            else:  # a real import regression stays fatal
                failed = True
                rows = [f"{name},0,ERROR:{type(e).__name__}:{e}"]
        except Exception as e:  # pragma: no cover
            failed = True
            rows = [f"{name},0,ERROR:{type(e).__name__}:{e}"]
        dt = time.perf_counter() - t0
        for row in rows:
            print(row)
            if "FAIL" in row or "ERROR" in row:
                failed = True
        print(f"{name}_wall,{1e6*dt:.0f},done")
    _dump_obs_snapshot()
    sys.exit(1 if failed else 0)


def _dump_obs_snapshot() -> None:
    """Attach the sweep's obs snapshot (every benchmark above ran with
    live instrumentation) so a perf regression comes with its per-stage
    codec timings and byte counters on the same commit."""
    import json
    from pathlib import Path

    from repro import obs

    snap = obs.snapshot()
    out = Path(__file__).resolve().parent / "BENCH_obs_snapshot.json"
    out.write_text(json.dumps(snap, indent=1, sort_keys=True) + "\n")
    print(f"obs_snapshot,0,{len(snap['counters'])}c_{len(snap['gauges'])}g_"
          f"{len(snap['histograms'])}h_{out.name}")


if __name__ == "__main__":
    main()
