"""Benchmark driver (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows; exit code 0 iff every
lossless check passed.  Rows whose derived field starts with ``SKIP``
(e.g. the service benchmarks on a read-only store root) count as
passed."""

import sys
import time


def main() -> None:
    from benchmarks import (baselines, batch_throughput, compression_ratio,
                            disk_sizes, entropy_efficiency, grad_compress,
                            memory, robustness, scaling, service_throughput,
                            space_savings, throughput)

    modules = [
        ("table5_compression_ratio", compression_ratio),
        ("table6_space_savings", space_savings),
        ("table7_throughput", throughput),
        ("sec5.5_memory", memory),
        ("table2_3_robustness", robustness),
        ("sec5.7_scaling", scaling),
        ("sec3.6_entropy", entropy_efficiency),
        ("sec5.3_disk", disk_sizes),
        ("beyond_paper_baselines", baselines),
        ("store_batch_throughput", batch_throughput),
        ("service_throughput", service_throughput),
        ("dist_grad_compress", grad_compress),
    ]
    print("name,us_per_call,derived")
    failed = False
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            rows = mod.run()
        except Exception as e:  # pragma: no cover
            failed = True
            rows = [f"{name},0,ERROR:{type(e).__name__}:{e}"]
        dt = time.perf_counter() - t0
        for row in rows:
            print(row)
            if "FAIL" in row or "ERROR" in row:
                failed = True
        print(f"{name}_wall,{1e6*dt:.0f},done")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
