"""Batch ingest throughput: group-commit `put_many` vs per-record `put`.

Measures prompts/sec into a ShardedPromptStore at batch sizes 1/32/256.
Per-record `put` pays two fsyncs per prompt (data, then index publish);
`put_many` pays two fsyncs per *shard touched per batch*, plus one batched
codec-pipeline pass (batch BPE + packing).  The token method isolates the
storage/commit path — byte-compressor time is identical either way and
would only dilute the measured difference.
"""

from __future__ import annotations

import tempfile
import time

from benchmarks.common import csv_row
from repro.core.api import PromptCompressor
from repro.core.store import ShardedPromptStore
from repro.tokenizer.vocab import default_tokenizer

N_PROMPTS = 256
N_SHARDS = 8
BATCH_SIZES = (1, 32, 256)


def _texts() -> list:
    return [f"user {i}: summarize incident ticket #{i % 17}; "
            f"attach the runbook diff and escalate. " * 4
            for i in range(N_PROMPTS)]


def _ingest(texts, batch: int, compressor) -> float:
    """Seconds to ingest all texts in `batch`-sized put_many calls
    (batch=0 means the per-record put loop)."""
    with tempfile.TemporaryDirectory() as tmp:
        store = ShardedPromptStore(tmp, compressor, n_shards=N_SHARDS)
        t0 = time.perf_counter()
        if batch == 0:
            for t in texts:
                store.put(t)
        else:
            for i in range(0, len(texts), batch):
                store.put_many(texts[i:i + batch])
        dt = time.perf_counter() - t0
        assert len(store) == len(set(texts))
        return dt


def run() -> list:
    tok = default_tokenizer()
    compressor = PromptCompressor(tok, method="token")
    texts = _texts()
    rows = []
    _ingest(texts[:32], 32, compressor)  # warm FS + tokenizer word cache
    t_put = _ingest(texts, 0, compressor)
    base_pps = len(texts) / t_put
    rows.append(csv_row("batch_throughput_put_per_record",
                        1e6 * t_put / len(texts), f"{base_pps:.0f}prompts/s"))
    for batch in BATCH_SIZES:
        t = _ingest(texts, batch, compressor)
        pps = len(texts) / t
        rows.append(csv_row(f"batch_throughput_put_many_b{batch}",
                            1e6 * t / len(texts),
                            f"{pps:.0f}prompts/s speedup={pps / base_pps:.2f}x"))
    return rows
