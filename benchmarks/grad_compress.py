"""int8 error-feedback gradient compression: step overhead + wire bytes.

Times the jitted train step with and without `compress_grads=True` on the
lopace smoke config, and the standalone `ef_compress_tree` transform on a
param-shaped gradient tree.  The wire story: int8 + one f32 scale per
tensor crosses the DP axis instead of f32 — ~4x fewer bytes; the EF
residual keeps the update lossless over time (see repro.dist.collectives).

Writes `benchmarks/BENCH_grad_compress.json` so the perf trajectory has a
committed, machine-readable anchor per run.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row

_OUT = Path(__file__).resolve().parent / "BENCH_grad_compress.json"
N_STEPS = 8


def _time_steps(step_fn, params, opt, batch) -> float:
    params, opt, m = step_fn(params, opt, batch)   # compile + warm
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(N_STEPS):
        params, opt, m = step_fn(params, opt, batch)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / N_STEPS


def run() -> list:
    from repro.configs.lopace import CONFIG
    from repro.dist.collectives import ef_compress_tree
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_loop import init_train_state, make_train_step

    cfg = dataclasses.replace(CONFIG.smoke(), name="lopace-efbench")
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 128)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0)

    times = {}
    for compress in (False, True):
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat="none",
                                          compress_grads=compress))
        params, opt = init_train_state(jax.random.PRNGKey(0), cfg,
                                       compress_grads=compress)
        times[compress] = _time_steps(step_fn, params, opt, batch)

    # standalone transform on a param-shaped tree (the collective payload)
    params, _ = init_train_state(jax.random.PRNGKey(1), cfg)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.ones(p.shape, jnp.float32) * 1e-3, params)
    ef = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
    ef_jit = jax.jit(ef_compress_tree)
    jax.block_until_ready(ef_jit(grads, ef))
    t0 = time.perf_counter()
    for _ in range(N_STEPS):
        out = ef_jit(grads, ef)
    jax.block_until_ready(out)
    t_ef = (time.perf_counter() - t0) / N_STEPS

    leaves = jax.tree_util.tree_leaves(grads)
    f32_bytes = sum(l.size * 4 for l in leaves)
    int8_bytes = sum(l.size + 4 for l in leaves)  # int8 payload + f32 scale
    overhead = times[True] / times[False] - 1.0

    doc = {
        "benchmark": "grad_compress",
        "config": cfg.name,
        "n_steps_timed": N_STEPS,
        "step_s_uncompressed": times[False],
        "step_s_compressed": times[True],
        "step_overhead_frac": overhead,
        "ef_transform_s": t_ef,
        "n_grad_leaves": len(leaves),
        "wire_bytes_f32": f32_bytes,
        "wire_bytes_int8": int8_bytes,
        "wire_ratio": f32_bytes / int8_bytes,
    }
    _OUT.write_text(json.dumps(doc, indent=1) + "\n")

    return [
        csv_row("grad_compress_step_base", 1e6 * times[False], "per_step"),
        csv_row("grad_compress_step_ef", 1e6 * times[True],
                f"overhead={overhead * 100:.1f}%"),
        csv_row("grad_compress_ef_transform", 1e6 * t_ef,
                f"wire={f32_bytes / int8_bytes:.2f}x_smaller"),
    ]
