"""Paper Table 7 / §5.4: compression and decompression throughput (MB/s).
Reference: zstd 10.7/132.9, token 4.6/8.5, hybrid 3.3/2.3 MB/s on the
paper's (unspecified) host — same order of magnitude expected here."""

from benchmarks.common import METHODS, all_cycles, csv_row, stats


def run() -> list:
    rows = []
    by_method = all_cycles()
    for m in METHODS:
        cs = by_method[m]
        tot_mb = sum(c.n_bytes for c in cs) / 1e6
        comp = tot_mb / sum(c.t_compress_s for c in cs)
        decomp = tot_mb / sum(c.t_decompress_s for c in cs)
        us = 1e6 * sum(c.t_compress_s for c in cs) / len(cs)
        rows.append(csv_row(f"table7_throughput_{m}", us,
                            f"compress={comp:.1f}MB/s decompress={decomp:.1f}MB/s"))
    return rows
