"""Paper Table 7 / §5.4: compression and decompression throughput (MB/s).
Reference: zstd 10.7/132.9, token 4.6/8.5, hybrid 3.3/2.3 MB/s on the
paper's (unspecified) host — same order of magnitude expected here.

Also reports BPE encode throughput alone (cold + warm word-cache): the
token/hybrid rows are tokenizer-bound, so this row shows how much of
their budget the merge loop takes and how much the per-word LRU memo
(`tokenizer/bpe.py`) recovers on realistic re-encoding traffic."""

import time

from benchmarks.common import METHODS, all_cycles, corpus, csv_row, stats


def _encode_row() -> str:
    from repro.tokenizer.vocab import default_tokenizer

    texts = [p.text for p in corpus()]
    tot_mb = sum(len(t.encode("utf-8")) for t in texts) / 1e6
    # default_tokenizer() is a process-cached singleton whose word memo
    # the earlier all_cycles() pass already warmed — drop it so the cold
    # row measures the merge loop, not cache hits
    tok = default_tokenizer()
    tok._encode_word.cache_clear()
    t0 = time.perf_counter()
    for t in texts:
        tok.encode(t)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for t in texts:
        tok.encode(t)
    t_warm = time.perf_counter() - t0
    return csv_row(
        "table7_throughput_bpe_encode", 1e6 * t_cold / len(texts),
        f"cold={tot_mb/t_cold:.1f}MB/s warm={tot_mb/t_warm:.1f}MB/s "
        f"cache_gain={t_cold/t_warm:.1f}x")


def run() -> list:
    rows = []
    by_method = all_cycles()
    for m in METHODS:
        cs = by_method[m]
        tot_mb = sum(c.n_bytes for c in cs) / 1e6
        comp = tot_mb / sum(c.t_compress_s for c in cs)
        decomp = tot_mb / sum(c.t_decompress_s for c in cs)
        us = 1e6 * sum(c.t_compress_s for c in cs) / len(cs)
        rows.append(csv_row(f"table7_throughput_{m}", us,
                            f"compress={comp:.1f}MB/s decompress={decomp:.1f}MB/s"))
    rows.append(_encode_row())
    return rows
