"""Shared benchmark harness: the paper's five-phase evaluation protocol
(§4.3) — init, compress, decompress, verify, metrics — with
time.perf_counter timing and tracemalloc peak tracking."""

from __future__ import annotations

import hashlib
import time
import tracemalloc
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.api import PromptCompressor
from repro.data.corpus import Prompt, generate_corpus
from repro.tokenizer.vocab import default_tokenizer

METHODS = ("zstd", "token", "hybrid")
N_PROMPTS = int(__import__("os").environ.get("REPRO_BENCH_PROMPTS", "386"))


@dataclass
class Cycle:
    """One compress/decompress cycle's measurements (paper §4.3 phases 2-5)."""
    method: str
    n_chars: int
    n_bytes: int
    compressed_bytes: int
    t_compress_s: float
    t_decompress_s: float
    mem_compress_mb: float
    mem_decompress_mb: float
    lossless: bool

    @property
    def cr(self) -> float:
        return self.n_bytes / self.compressed_bytes

    @property
    def space_savings(self) -> float:
        return (1 - self.compressed_bytes / self.n_bytes) * 100.0

    @property
    def bpc(self) -> float:
        return self.compressed_bytes * 8.0 / max(self.n_chars, 1)

    @property
    def comp_mbps(self) -> float:
        return self.n_bytes / 1e6 / max(self.t_compress_s, 1e-9)

    @property
    def decomp_mbps(self) -> float:
        return self.n_bytes / 1e6 / max(self.t_decompress_s, 1e-9)


_corpus_cache: Dict[int, List[Prompt]] = {}


def corpus(n: int = N_PROMPTS, seed: int = 0) -> List[Prompt]:
    key = (n, seed)
    if key not in _corpus_cache:
        _corpus_cache[key] = generate_corpus(n, seed=seed)
    return _corpus_cache[key]


def run_cycle(pc: PromptCompressor, text: str, method: str,
              track_memory: bool = True) -> Cycle:
    raw = text.encode("utf-8")
    if track_memory:
        tracemalloc.start()
    t0 = time.perf_counter()
    payload = pc.compress_raw(text, method)
    t1 = time.perf_counter()
    mem_c = tracemalloc.get_traced_memory()[1] / 1e6 if track_memory else 0.0
    if track_memory:
        tracemalloc.stop()
        tracemalloc.start()
    t2 = time.perf_counter()
    rt = pc.decompress_raw(payload, method)
    t3 = time.perf_counter()
    mem_d = tracemalloc.get_traced_memory()[1] / 1e6 if track_memory else 0.0
    if track_memory:
        tracemalloc.stop()
    lossless = (rt == text and hashlib.sha256(rt.encode()).digest()
                == hashlib.sha256(raw).digest())
    return Cycle(method=method, n_chars=len(text), n_bytes=len(raw),
                 compressed_bytes=len(payload), t_compress_s=t1 - t0,
                 t_decompress_s=t3 - t2, mem_compress_mb=mem_c,
                 mem_decompress_mb=mem_d, lossless=lossless)


_cycles_cache: Dict[str, List[Cycle]] = {}


def all_cycles(n: int = N_PROMPTS, track_memory: bool = True) -> Dict[str, List[Cycle]]:
    """386 prompts x 3 methods = 1158 cycles (paper §4.3), cached."""
    key = f"{n}:{track_memory}"
    if key in _cycles_cache:
        return {m: [c for c in _cycles_cache[key] if c.method == m] for m in METHODS}
    pc = PromptCompressor(default_tokenizer(), level=15)
    cycles: List[Cycle] = []
    for p in corpus(n):
        for m in METHODS:
            cycles.append(run_cycle(pc, p.text, m, track_memory))
    _cycles_cache[key] = cycles
    return {m: [c for c in cycles if c.method == m] for m in METHODS}


def stats(vals) -> Dict[str, float]:
    arr = np.asarray(list(vals), dtype=np.float64)
    return {"mean": float(arr.mean()), "min": float(arr.min()),
            "max": float(arr.max()), "std": float(arr.std())}


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
