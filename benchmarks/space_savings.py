"""Paper Table 6 / §5.2: space savings by method + the SS(n)=a*ln(n)+b
logarithmic fit (Eq. 35; paper: a~2.5, b~60, R^2=0.94 for hybrid)."""

import numpy as np

from benchmarks.common import METHODS, all_cycles, csv_row, stats


def run() -> list:
    rows = []
    by_method = all_cycles()
    for m in METHODS:
        st = stats(c.space_savings for c in by_method[m])
        rows.append(csv_row(
            f"table6_ss_{m}", 0,
            f"mean={st['mean']:.1f}% min={st['min']:.1f}% max={st['max']:.1f}%"))
    # Eq. 35 fit on the hybrid method
    cs = by_method["hybrid"]
    x = np.log([c.n_chars for c in cs])
    y = np.array([c.space_savings for c in cs])
    A = np.stack([x, np.ones_like(x)], 1)
    (a, b), *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ np.array([a, b])
    r2 = 1 - ((y - pred) ** 2).sum() / ((y - y.mean()) ** 2).sum()
    rows.append(csv_row("eq35_hybrid_ss_logfit", 0,
                        f"a={a:.2f} b={b:.1f} R2={r2:.3f}"))
    return rows
