"""Device-codec kernel benchmarks: parity gate + accelerator sweeps.

Two jobs, split by what the host can actually measure:

* **always** (any backend): interpret-mode byte-parity of the device
  codec kernels against their host oracles — the LZ77 match finder must
  reproduce ``_lz_compress_np``'s stream, the lane-parallel rANS coder
  must reproduce the interleaved blob, histogram and token-pack device
  paths must match NumPy.  A mismatch emits a ``FAIL`` row, which kills
  the ``benchmarks/run.py`` sweep — this is the lossless gate.
* **accelerator only**: wall-clock sweeps — device vs host throughput
  per kernel, the ``DEFAULT_BLOCK_N`` block-size sweep for
  ``pack_fixed_batch_device``, and the measured device crossovers backing
  the ``REPRO_*_DEVICE_MIN`` defaults.  On CPU hosts these rows report
  ``SKIP:no_accelerator`` (interpret-mode timings would be noise), but
  block-size *correctness* is still checked per candidate block.

Writes ``benchmarks/BENCH_kernel_codec.json``.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import corpus, csv_row

_OUT = Path(__file__).resolve().parent / "BENCH_kernel_codec.json"

REPS = 3
BLOCK_SWEEP = (512, 1024, 2048, 4096, 8192)   # pack kernel block_n candidates
_PARITY_BYTES = 1 << 16   # interpret mode is slow; keep the gate payload small


def _best(fn, reps=REPS):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _payload(n: int) -> bytes:
    blob = "\n".join(p.text for p in corpus(32)).encode("utf-8")
    reps = -(-n // len(blob))
    return (blob * reps)[:n]


def _parity_rows(doc: dict) -> list:
    """Interpret-mode byte-parity of every device codec stage (the
    lossless gate — runs on any backend)."""
    from repro.core.entropy import byte_histogram
    from repro.core.lz77 import _lz_compress_device, _lz_compress_np
    from repro.core.rans_np import (normalize_freqs,
                                    rans_decode_interleaved,
                                    rans_encode_interleaved)
    from repro.kernels.rans_lanes import (rans_decode_interleaved_device,
                                          rans_encode_interleaved_device)

    rows = []
    payload = _payload(_PARITY_BYTES)
    sym = np.frombuffer(payload, np.uint8)
    freqs = normalize_freqs(np.bincount(sym, minlength=256))

    lz_ok = _lz_compress_device(payload) == _lz_compress_np(payload)
    rans_ok = True
    for lanes in (16, 256, 1024):
        w_r, x_r = rans_encode_interleaved(sym, freqs, lanes)
        w_d, x_d = rans_encode_interleaved_device(sym, freqs, lanes, 12,
                                                  interpret=True)
        dec = rans_decode_interleaved_device(w_d, x_d, sym.size, freqs,
                                             lanes, 12, interpret=True)
        rans_ok &= (np.array_equal(w_r, w_d) and np.array_equal(x_r, x_d)
                    and bytes(dec) == payload
                    and rans_decode_interleaved(
                        w_d, x_d, sym.size, freqs, lanes).tobytes() == payload)
    hist_ok = np.array_equal(np.asarray(byte_histogram(payload, use_device=True)),
                             byte_histogram(payload, use_device=False))
    doc["parity"] = {"lz": lz_ok, "rans": rans_ok, "hist": hist_ok}
    for name, ok in doc["parity"].items():
        rows.append(csv_row(f"kernel_{name}_parity", 0,
                            "ok" if ok else "FAIL:byte_mismatch"))
    return rows


def _block_sweep_rows(doc: dict, on_device: bool) -> list:
    """DEFAULT_BLOCK_N sweep for the token-pack byte-split kernel:
    correctness per candidate block always; timings only where a real
    accelerator makes them meaningful."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.token_pack.kernel import pack_tokens_kernel
    from repro.kernels.token_pack.ref import pack_ref

    rows = []
    rng = np.random.default_rng(7)
    ids = rng.integers(0, 1 << 20, 1 << 16).astype(np.int32)
    sweep = {}
    for block_n in BLOCK_SWEEP:
        idsp = ids[: (ids.size // block_n) * block_n]
        x = jnp.asarray(idsp)
        correct = np.array_equal(
            np.asarray(pack_tokens_kernel(x, width=4, block_n=block_n,
                                          interpret=not on_device)),
            np.asarray(pack_ref(x, 4)))
        if not correct:
            rows.append(csv_row(f"kernel_pack_block{block_n}", 0,
                                "FAIL:byte_mismatch"))
            continue
        if on_device:
            fn = jax.jit(lambda a, b=block_n: pack_tokens_kernel(
                a, width=4, block_n=b, interpret=False))
            fn(x).block_until_ready()
            t = _best(lambda: fn(x).block_until_ready())
            mbps = idsp.nbytes / 1e6 / t
            sweep[block_n] = mbps
            rows.append(csv_row(f"kernel_pack_block{block_n}", 1e6 * t,
                                f"{mbps:.0f}MB/s ok"))
        else:
            rows.append(csv_row(f"kernel_pack_block{block_n}", 0,
                                "SKIP:no_accelerator ok"))
    doc["pack_block_sweep_mbps"] = sweep
    if sweep:
        doc["pack_block_best"] = max(sweep, key=sweep.get)
    return rows


def _device_sweep_rows(doc: dict, on_device: bool) -> list:
    """Device-vs-host throughput + crossover hints for the LZ and rANS
    stages (accelerator only)."""
    rows = []
    if not on_device:
        for name in ("lz_match", "rans_lanes", "histogram"):
            rows.append(csv_row(f"kernel_{name}_throughput", 0,
                                "SKIP:no_accelerator"))
        return rows
    from repro.core.entropy import byte_histogram
    from repro.core.lz77 import _lz_compress_device, _lz_compress_np
    from repro.core.rans_np import normalize_freqs, rans_encode_interleaved
    from repro.kernels.rans_lanes import rans_encode_interleaved_device

    crossovers = {}
    for name, host_fn, dev_fn in (
        ("lz_match",
         lambda p: _lz_compress_np(p),
         lambda p: _lz_compress_device(p)),
        ("rans_lanes",
         lambda p: rans_encode_interleaved(
             np.frombuffer(p, np.uint8),
             normalize_freqs(np.bincount(np.frombuffer(p, np.uint8),
                                         minlength=256)), 256),
         lambda p: rans_encode_interleaved_device(
             np.frombuffer(p, np.uint8),
             normalize_freqs(np.bincount(np.frombuffer(p, np.uint8),
                                         minlength=256)), 256, 12,
             interpret=False)),
        ("histogram",
         lambda p: byte_histogram(p, use_device=False),
         lambda p: byte_histogram(p, use_device=True)),
    ):
        cross = None
        for size in (1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22):
            p = _payload(size)
            dev_fn(p)   # warm the jit cache before timing
            t_h = _best(lambda: host_fn(p))
            t_d = _best(lambda: dev_fn(p))
            mb = size / 1e6
            rows.append(csv_row(
                f"kernel_{name}_{size}", 1e6 * t_d,
                f"host={mb/t_h:.1f}MB/s device={mb/t_d:.1f}MB/s "
                f"speedup={t_h/t_d:.2f}x"))
            if cross is None and t_d < t_h:
                cross = size
        crossovers[name] = cross
    doc["measured_crossover_bytes"] = crossovers
    return rows


def run() -> list:
    import jax

    on_device = jax.default_backend() != "cpu"
    doc = {"backend": jax.default_backend(), "reps": REPS,
           "block_sweep": list(BLOCK_SWEEP)}
    rows = _parity_rows(doc)
    rows += _block_sweep_rows(doc, on_device)
    rows += _device_sweep_rows(doc, on_device)
    try:
        _OUT.write_text(json.dumps(doc, indent=1) + "\n")
    except OSError:
        pass  # benchmarks dir read-only: keep the csv rows
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
