"""Enabled-mode obs overhead: what does live telemetry cost the codec?

Three measurements of the same ~1 MiB repro-lzr compress:

* ``raw``      — ``compress_bytes`` directly (no codec framing, no obs);
* ``enabled``  — ``ByteCompressorCodec.encode_batch`` built with
                 REPRO_OBS=1: every batch observes a latency histogram
                 and four byte counters;
* ``disabled`` — the same codec built with REPRO_OBS=0 (no-op stubs);
                 informational here, gated hard in scripts/obs_smoke.py.

Per-batch instrumentation cost is O(1) (two perf_counter reads, one
histogram observe, four counter incs, two ``sum(len(...))`` passes over
the payload list), so on a single 1 MiB payload (~hundreds of ms of
codec work) enabled-mode overhead should be well under the 5% design
target; the FAIL threshold is 10% — trip it and the obs layer has
grown per-byte work.  Instruments created here stay registered, so the
sweep-end ``BENCH_obs_snapshot.json`` (benchmarks/run.py) records this
module's traffic too.
"""

from __future__ import annotations

import os
import time

from benchmarks.common import csv_row

TARGET = 0.05   # design target for enabled-mode overhead
FAIL_AT = 0.10  # derived column says FAIL above this
REPS = 5


def _best(fn, reps=REPS):
    fn()  # warmup
    b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        b = min(b, time.perf_counter() - t0)
    return b


def run():
    from repro.core.codec import ByteCompressorCodec
    from repro.core.zstd_backend import compress_bytes
    from repro.data.corpus import generate_corpus

    blob = "\n".join(
        p.text for p in generate_corpus(32, seed=0)).encode()[:1 << 20]
    t_raw = _best(lambda: compress_bytes(blob, backend="repro-lzr"))

    # REPRO_OBS is resolved at instrument creation, i.e. codec
    # construction — build a fresh codec under each setting
    prior = os.environ.get("REPRO_OBS")
    times = {}
    try:
        for mode in ("1", "0"):
            os.environ["REPRO_OBS"] = mode
            codec = ByteCompressorCodec(backend="repro-lzr")
            times[mode] = _best(lambda: codec.encode_batch([blob]))
    finally:
        if prior is None:
            os.environ.pop("REPRO_OBS", None)
        else:
            os.environ["REPRO_OBS"] = prior

    rows = [csv_row("obs_raw_1mib", t_raw * 1e6, "baseline")]
    on = times["1"] / t_raw - 1.0
    verdict = ("FAIL" if on > FAIL_AT
               else "ok" if on <= TARGET else "above_target")
    rows.append(csv_row(
        "obs_enabled_1mib", times["1"] * 1e6,
        f"{verdict}:{on * 100:+.1f}%_target<{TARGET * 100:.0f}%"
        f"_fail>{FAIL_AT * 100:.0f}%"))
    off = times["0"] / t_raw - 1.0
    rows.append(csv_row(
        "obs_disabled_1mib", times["0"] * 1e6,
        f"info:{off * 100:+.1f}%_gated_in_obs_smoke"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
