"""Gateway tier throughput: socket-framed admission under concurrent
clients, explicit admission-control rejects, and read-replica scaling.

Admission: N concurrent socket clients push `put_async(wait=True)`
batches through a real `GatewayServer` (length-prefixed JSON frames,
thread-pool execution, per-connection windows) at production queue
depths, then read every key back — the row carries FAIL if any
round-trip is not byte-identical.  A second row serves `get_tokens`
through the same gateway, the hot replica-read op.

Rejects: a gateway capped at ``max_inflight=1`` is saturated with a
slow write while probe pings arrive; the row reports how many probes
the admission gate bounced (`admission_reject` is immediate — the
gateway never queues above its cap) and fails if none were.

Replica scaling: one writer fills a store, then R ∈ {1, 2, 4} reader
threads each open their own ``ShardedPromptStore(readonly=True)``
replica (own fds, own index, no shared locks with the writer — the
same isolation a separate process gets) and sweep `get_tokens_many`
rounds over disjoint key slices.  Derived fields report aggregate
reads/s and the speedup over the 1-replica baseline; the ≥2-replica
rows are the scaling evidence.  Each thread verifies its decodes
against the source texts, so a stale or torn replica view fails loudly.

Skips gracefully (SKIP row) on a read-only store root — set
REPRO_BENCH_STORE_ROOT to move it.  Writes
`benchmarks/BENCH_gateway_throughput.json`.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from pathlib import Path

from benchmarks.common import csv_row

_OUT = Path(__file__).resolve().parent / "BENCH_gateway_throughput.json"

N_PROMPTS = 192
N_SHARDS = 4
N_CLIENTS = 4       # concurrent gateway clients
CLIENT_BATCHES = 4  # put_async batches per client
BATCH = 12          # texts per batch (N_CLIENTS*CLIENT_BATCHES*BATCH total)
GET_ROUNDS = 3      # get_tokens sweeps per client over its own keys
REJECT_PROBES = 6
REPLICA_COUNTS = (1, 2, 4)
REPLICA_ROUNDS = 4  # get_tokens_many sweeps per replica thread


def _store_root() -> str:
    return os.environ.get("REPRO_BENCH_STORE_ROOT", tempfile.gettempdir())


def _writable(root: str) -> bool:
    try:
        with tempfile.TemporaryDirectory(dir=root):
            return True
    except OSError:
        return False


def _texts(n: int) -> list:
    return [f"req {i}: roll the deployment for tenant #{i % 13}, "
            "capture the audit trail, page on regression. " * 4
            for i in range(n)]


def run() -> list:
    root = _store_root()
    if not _writable(root):
        return [csv_row("gateway_throughput", 0,
                        f"SKIP:store_root_read_only:{root}")]

    from repro.core.api import PromptCompressor
    from repro.core.store import ShardedPromptStore
    from repro.service import PromptService
    from repro.service.gateway import GatewayClient, start_in_thread
    from repro.tokenizer.vocab import default_tokenizer

    tok = default_tokenizer()
    rows = []

    # -- concurrent-client admission through the socket front end ------------
    with tempfile.TemporaryDirectory(dir=root) as tmp:
        store = ShardedPromptStore(tmp, PromptCompressor(tok, method="hybrid"),
                                   n_shards=N_SHARDS)
        service = PromptService(store, cache_bytes=32 << 20,
                                flush_batch=2 * BATCH, max_pending=8 * BATCH)
        lossless = True
        with service, start_in_thread(service, max_inflight=16,
                                      conn_window=4) as handle:
            results = [None] * N_CLIENTS
            errors = []

            def client(ci: int) -> None:
                try:
                    acked = {}
                    with GatewayClient("127.0.0.1", handle.port) as c:
                        for bi in range(CLIENT_BATCHES):
                            texts = _texts(BATCH * (ci * CLIENT_BATCHES + bi
                                                    + 1))[-BATCH:]
                            keys = c.put_async(texts, wait=True)["keys"]
                            acked.update(zip(keys, texts))
                        ok = all(c.get_many(list(acked)) == list(
                            acked.values()) for _ in range(1))
                        t0 = time.perf_counter()
                        for _ in range(GET_ROUNDS):
                            for k in acked:
                                c.get_tokens(k)
                        dt = time.perf_counter() - t0
                    results[ci] = (acked, ok, dt)
                except Exception as e:  # noqa: BLE001 - surfaces as FAIL row
                    errors.append(e)

            t0 = time.perf_counter()
            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(N_CLIENTS)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(120)
            t_wall = time.perf_counter() - t0

            if errors or any(r is None for r in results):
                return rows + [csv_row("gateway_put_async_e2e", 0,
                                       f"FAIL:client_errors:{errors}")]
            lossless = all(ok for _, ok, _ in results)
            n_put = sum(len(a) for a, _, _ in results)
            n_gets = sum(GET_ROUNDS * len(a) for a, _, _ in results)
            t_get = max(dt for _, _, dt in results)
            if len(store) != n_put:
                lossless = False

        verdict = "" if lossless else " FAIL:lossless"
        put_pps = n_put / t_wall
        get_pps = n_gets / t_get
        rows.append(csv_row(
            "gateway_put_async_e2e", 1e6 * t_wall / n_put,
            f"{N_CLIENTS}clients {put_pps:.0f}prompts/s durable+verified"
            + verdict))
        rows.append(csv_row(
            "gateway_get_tokens", 1e6 * t_get / n_gets,
            f"{N_CLIENTS}clients {get_pps:.0f}reads/s via socket" + verdict))

    # -- admission-control rejects at a tiny inflight cap --------------------
    with tempfile.TemporaryDirectory(dir=root) as tmp:
        store = ShardedPromptStore(tmp, PromptCompressor(tok, method="token"),
                                   n_shards=2)
        # big flush_batch + long interval: put_async(wait=True) parks its
        # executor slot until the timed flush fires, saturating the cap
        service = PromptService(store, cache_bytes=0, flush_batch=4096,
                                flush_interval_s=0.5, max_pending=8192)
        rejects = accepted = 0
        with service, start_in_thread(service, max_inflight=1,
                                      conn_window=8) as handle:
            blocker_done = threading.Event()

            def blocker() -> None:
                with GatewayClient("127.0.0.1", handle.port) as c:
                    c.put_async(["occupy the only inflight slot " * 8],
                                wait=True, timeout=30)
                blocker_done.set()

            th = threading.Thread(target=blocker)
            th.start()
            time.sleep(0.1)  # let the blocker reach the executor
            t0 = time.perf_counter()
            with GatewayClient("127.0.0.1", handle.port) as c:
                for _ in range(REJECT_PROBES):
                    resp = c.request("ping")
                    if resp.get("ok"):
                        accepted += 1
                    elif resp.get("error") == "admission_reject":
                        rejects += 1
                t_probe = time.perf_counter() - t0
                th.join(60)
                blocker_done.wait(5)
                recovered = c.call("ping")["pong"] is True
        rows.append(csv_row(
            "gateway_admission_reject", 1e6 * t_probe / REJECT_PROBES,
            f"{rejects}/{REJECT_PROBES}rejected_immediately "
            f"recovered={recovered}"
            + ("" if rejects and recovered else " FAIL:no_rejects")))

    # -- read-replica scaling: R readonly stores over one writer's data ------
    scaling = {}
    with tempfile.TemporaryDirectory(dir=root) as tmp:
        writer = ShardedPromptStore(tmp, PromptCompressor(tok, method="hybrid"),
                                    n_shards=N_SHARDS)
        texts = _texts(N_PROMPTS)
        keys = writer.put_many(texts)
        by_key = dict(zip(keys, texts))
        replica_fail = None

        for n_rep in REPLICA_COUNTS:
            slices = [keys[i::n_rep] for i in range(n_rep)]
            stores = [ShardedPromptStore(tmp, PromptCompressor(
                tok, method="hybrid"), readonly=True) for _ in range(n_rep)]
            barrier = threading.Barrier(n_rep + 1)
            errs = []

            def reader(rs, my_keys) -> None:
                try:
                    rs.get_tokens_many(my_keys)  # warm per-replica index
                    barrier.wait()
                    for _ in range(REPLICA_ROUNDS):
                        rs.get_tokens_many(my_keys)
                    got = rs.get_many(my_keys)
                    if got != [by_key[k] for k in my_keys]:
                        raise AssertionError("replica read not lossless")
                    barrier.wait()
                except Exception as e:  # noqa: BLE001
                    errs.append(e)
                    try:
                        barrier.abort()
                    except Exception:
                        pass

            threads = [threading.Thread(target=reader, args=(rs, sl))
                       for rs, sl in zip(stores, slices)]
            for t in threads:
                t.start()
            barrier.wait()
            t0 = time.perf_counter()
            barrier.wait()
            dt = time.perf_counter() - t0
            for t in threads:
                t.join(120)
            for rs in stores:
                rs.close()
            if errs:
                replica_fail = errs[0]
                break
            n_reads = REPLICA_ROUNDS * len(keys)  # split across replicas
            scaling[n_rep] = n_reads / dt
        writer.close()

    if replica_fail is not None:
        rows.append(csv_row("gateway_replica_scaling", 0,
                            f"FAIL:replica_error:{replica_fail}"))
    else:
        base = scaling[1]
        for n_rep in REPLICA_COUNTS:
            pps = scaling[n_rep]
            n_reads = REPLICA_ROUNDS * N_PROMPTS
            rows.append(csv_row(
                f"gateway_replica_read_x{n_rep}", 1e6 / pps,
                f"{pps:.0f}reads/s scaling={pps / base:.2f}x "
                f"({n_rep}replicas, lossless)"))

    doc = {
        "benchmark": "gateway_throughput",
        "host_cpus": os.cpu_count(),  # replica scaling is core-bound
        "n_clients": N_CLIENTS,
        "client_batches": CLIENT_BATCHES,
        "batch": BATCH,
        "put_async_prompts_per_s": put_pps,
        "put_async_lossless": lossless,
        "get_tokens_reads_per_s": get_pps,
        "admission_probes": REJECT_PROBES,
        "admission_rejects": rejects,
        "admission_recovered": recovered,
        "replica_prompts": N_PROMPTS,
        "replica_rounds": REPLICA_ROUNDS,
        "replica_reads_per_s": {str(k): v for k, v in scaling.items()},
        "replica_scaling": {str(k): v / scaling[1]
                            for k, v in scaling.items()} if scaling else {},
    }
    try:
        _OUT.write_text(json.dumps(doc, indent=1) + "\n")
    except OSError:
        pass  # benchmarks dir itself read-only: keep the csv rows

    return rows
