"""Codec hot-path throughput: scalar baseline vs the vectorized rewrite.

The paper's production gate is lossless-codec throughput (Table 7 /
§5.4), and with ``zstandard`` absent the from-scratch ``repro-lz`` /
``repro-lzr`` backends carry every store write and compaction pass — so
this module is the repo's perf trajectory point for the codec tier.

Measured per backend, on the two payload families the system actually
stores (method 1 compresses UTF-8 text; method 3's byte stage compresses
*packed token streams*):

* ``scalar``     — the seed implementation, forced via REPRO_LZ_MODE /
                   single-lane rANS (this is the speedup denominator);
* ``vectorized`` — the NumPy LZ77 parse + interleaved N-lane rANS
                   (auto-routing, exactly what production calls hit);
* ``batch``      — `compress_bytes` fanned over the corpus records
                   through the shared codec thread pool (the store's
                   plan_batch / ingest-dispatcher path) vs a sequential
                   scalar loop.

Every row carries a lossless check: FAIL in the derived column kills the
sweep.  Writes ``benchmarks/BENCH_codec_throughput.json``.

Findings this records (see ARCHITECTURE.md "Vectorized codec path",
measured on the reference 2-vCPU container): the rANS rewrite is a
10-20x win both ways in isolation and dominates ``repro-lzr`` — 5.9x
compress / 4.4x decompress end-to-end on packed token streams, 3.3x /
3.8x on prompt text; the LZ77 vectorized parse wins 1.7x (text) to 4.7x
(packed) on compress; LZ *decode* stays on the scalar loop in auto (its
bulk slice copies already run at memcpy speed — the vectorized
parse+gather path measured at parity or worse, kept only behind
REPRO_LZ_MODE=vector), so ``repro-lz`` decompress is ~1x by design and
the decode-side win rides on the rANS stage.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from benchmarks.common import corpus, csv_row

_OUT = Path(__file__).resolve().parent / "BENCH_codec_throughput.json"

N_TEXT = 96          # corpus records per family (bounded for CI wall time)
REPS = 3             # best-of reps per measurement
BACKENDS = ("repro-lz", "repro-lzr")


def _families():
    from repro.core import packing
    from repro.tokenizer.vocab import default_tokenizer

    texts = [p.text for p in corpus(N_TEXT)]
    tok = default_tokenizer()
    text_recs = [t.encode("utf-8") for t in texts]
    packed_recs = [
        packing.pack_tokens(np.asarray(tok.encode(t), np.uint32), "fixed")
        for t in texts]
    return {"text": text_recs, "packed": packed_recs}


def _best(fn, reps=REPS):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


class _env:
    """Temporarily pin the codec routing env knobs."""

    def __init__(self, **kv):
        self.kv = kv
        self.old = {}

    def __enter__(self):
        for k, v in self.kv.items():
            self.old[k] = os.environ.get(k)
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v

    def __exit__(self, *exc):
        for k, v in self.old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


_SCALAR = dict(REPRO_LZ_MODE="scalar", REPRO_RANS_LANES="1",
               REPRO_CODEC_THREADS="0")
_VECTOR = dict(REPRO_LZ_MODE=None, REPRO_RANS_LANES=None,
               REPRO_CODEC_THREADS="0")
_BATCH = dict(REPRO_LZ_MODE=None, REPRO_RANS_LANES=None,
              REPRO_CODEC_THREADS=None)


def run() -> list:
    from repro.core.codec import ByteCompressorCodec
    from repro.core.zstd_backend import compress_bytes, decompress_bytes

    rows = []
    doc = {"n_records": N_TEXT, "reps": REPS}
    failed = False
    for family, recs in _families().items():
        blob = b"".join(recs)
        mb = len(blob) / 1e6
        doc[f"{family}_bytes"] = len(blob)
        for backend in BACKENDS:
            codec = ByteCompressorCodec(backend=backend)
            # -- single-stream scalar vs vectorized ------------------------
            with _env(**_SCALAR):
                t_cs = _best(lambda: compress_bytes(blob, backend=backend))
                comp_s = compress_bytes(blob, backend=backend)
                t_ds = _best(lambda: decompress_bytes(comp_s, backend=backend))
            with _env(**_VECTOR):
                t_cv = _best(lambda: compress_bytes(blob, backend=backend))
                comp_v = compress_bytes(blob, backend=backend)
                t_dv = _best(lambda: decompress_bytes(comp_v, backend=backend))
                lossless = decompress_bytes(comp_v, backend=backend) == blob
            # -- batch over records: pooled vectorized vs sequential scalar
            with _env(**_SCALAR):
                t_bs = _best(lambda: [compress_bytes(r, backend=backend)
                                      for r in recs])
            with _env(**_BATCH):
                t_bv = _best(lambda: codec.encode_batch(recs))
                batch_ok = (codec.decode_batch(codec.encode_batch(recs))
                            == list(recs))
            if not (lossless and batch_ok):
                failed = True
            tag = f"{family}_{backend}"
            doc.update({
                f"{tag}_ratio_scalar": len(blob) / len(comp_s),
                f"{tag}_ratio_vectorized": len(blob) / len(comp_v),
                f"{tag}_compress_scalar_mbps": mb / t_cs,
                f"{tag}_compress_vectorized_mbps": mb / t_cv,
                f"{tag}_compress_speedup": t_cs / t_cv,
                f"{tag}_decompress_scalar_mbps": mb / t_ds,
                f"{tag}_decompress_vectorized_mbps": mb / t_dv,
                f"{tag}_decompress_speedup": t_ds / t_dv,
                f"{tag}_batch_scalar_mbps": mb / t_bs,
                f"{tag}_batch_vectorized_mbps": mb / t_bv,
                f"{tag}_batch_speedup": t_bs / t_bv,
            })
            state = "ok" if (lossless and batch_ok) else "FAIL:lossless"
            rows.append(csv_row(
                f"codec_{tag}_compress", 1e6 * t_cv,
                f"scalar={mb/t_cs:.2f}MB/s vec={mb/t_cv:.2f}MB/s "
                f"speedup={t_cs/t_cv:.1f}x {state}"))
            rows.append(csv_row(
                f"codec_{tag}_decompress", 1e6 * t_dv,
                f"scalar={mb/t_ds:.2f}MB/s vec={mb/t_dv:.2f}MB/s "
                f"speedup={t_ds/t_dv:.1f}x"))
            rows.append(csv_row(
                f"codec_{tag}_batch", 1e6 * t_bv,
                f"scalar={mb/t_bs:.2f}MB/s pooled={mb/t_bv:.2f}MB/s "
                f"speedup={t_bs/t_bv:.1f}x"))
    doc["lossless"] = not failed
    try:
        _OUT.write_text(json.dumps(doc, indent=1) + "\n")
    except OSError:
        pass  # benchmarks dir read-only: keep the csv rows
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
