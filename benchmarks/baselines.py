"""Beyond-paper baselines the paper names as missing (§8.4.2 #1-#4, #12,
#13): stdlib codecs, zstd dictionary training, our from-scratch LZ/rANS
stack, varint/delta packing, the JAX device coder, adaptive selection."""

import time

import numpy as np

from benchmarks.common import corpus, csv_row
from repro.core import packing
from repro.core.adaptive import AdaptiveCompressor
from repro.core.api import PromptCompressor, compress_hybrid
from repro.core.rans import tokens_compress_device, tokens_decompress_device
from repro.core.zstd_backend import (BACKENDS, HAVE_ZSTD, ZstdDictBackend,
                                     compress_bytes)
from repro.tokenizer.vocab import default_tokenizer

_N = 48  # prompts per baseline (heavier codecs)


def run() -> list:
    tok = default_tokenizer()
    prompts = corpus()[:_N]
    texts = [p.text for p in prompts]
    total = sum(len(t.encode()) for t in texts)
    rows = []

    # byte-level codec sweep (paper §8.4.2 #3)
    for backend in sorted(BACKENDS):
        level = {"zstd": 15, "zlib": 9, "lzma": 6, "bz2": 9}.get(backend, 0)
        t0 = time.perf_counter()
        sizes = [len(compress_bytes(t.encode(), level=level, backend=backend))
                 for t in texts]
        dt = time.perf_counter() - t0
        rows.append(csv_row(f"baseline_{backend}", 1e6 * dt / len(texts),
                            f"CR={total/sum(sizes):.2f}x {total/1e6/dt:.1f}MB/s"))

    # zstd dictionary training (paper §8.4.2 #2) — needs the real C library
    if HAVE_ZSTD:
        half = max(1, len(texts) // 2)
        dict_be = ZstdDictBackend(texts[:half], dict_size=32768, level=15)
        eval_set = texts[half:] or texts[:1]
        sizes = [len(dict_be.compress(t.encode())) for t in eval_set]
        plain = [len(compress_bytes(t.encode(), level=15)) for t in eval_set]
        held = sum(len(t.encode()) for t in eval_set)
        rows.append(csv_row("baseline_zstd_dict", 0,
                            f"CR={held/sum(sizes):.2f}x vs_plain_zstd={sum(plain)/sum(sizes):.3f}x"))
    else:
        rows.append(csv_row("baseline_zstd_dict", 0,
                            "SKIP:zstandard not installed (requirements-dev.txt)"))

    # packing schemes on hybrid (paper §8.4.2 #1/#13)
    for scheme in ("fixed", "varint", "delta-varint"):
        sizes = [len(compress_hybrid(t, tok, level=15, scheme=scheme))
                 for t in texts]
        rows.append(csv_row(f"hybrid_packing_{scheme}", 0,
                            f"CR={total/sum(sizes):.2f}x"))

    # JAX device rANS coder over token streams (paper §8.4.2 #12)
    t0 = time.perf_counter()
    blobs = [tokens_compress_device(np.asarray(tok.encode(t))) for t in texts[:16]]
    dt = time.perf_counter() - t0
    sub = sum(len(t.encode()) for t in texts[:16])
    for b, t in zip(blobs, texts[:16]):
        assert tok.decode(tokens_decompress_device(b)) == t
    rows.append(csv_row("device_rans_coder", 1e6 * dt / 16,
                        f"CR={sub/sum(len(b) for b in blobs):.2f}x lossless=true"))

    # adaptive selection accuracy (paper §6.2.1)
    ac = AdaptiveCompressor(tok)
    best = chosen_best = 0
    pc = PromptCompressor(tok)
    for t in texts[: min(24, len(texts))]:
        sizes = {m: len(pc.compress_raw(t, m)) for m in ("zstd", "token", "hybrid")}
        choice = ac.choose(t).method
        best_m = min(sizes, key=sizes.get)
        best += 1
        if sizes[choice] <= 1.02 * sizes[best_m]:
            chosen_best += 1
    rows.append(csv_row("adaptive_selection", 0,
                        f"within2pct_of_best={100*chosen_best/best:.0f}%"))
    return rows
