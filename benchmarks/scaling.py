"""Paper §5.7 Eq. 38: CR(n) = c1 * n^c2 power-law fit (paper: c2 ~ 0.15
for hybrid) + size-quartile means."""

import numpy as np

from benchmarks.common import all_cycles, csv_row


def run() -> list:
    cs = all_cycles()["hybrid"]
    x = np.log([c.n_chars for c in cs])
    y = np.log([c.cr for c in cs])
    A = np.stack([x, np.ones_like(x)], 1)
    (c2, logc1), *_ = np.linalg.lstsq(A, y, rcond=None)
    rows = [csv_row("eq38_cr_powerlaw", 0,
                    f"c1={np.exp(logc1):.2f} c2={c2:.3f}")]
    order = np.argsort([c.n_chars for c in cs])
    qs = np.array_split(order, 4)
    for i, q in enumerate(qs):
        mean_cr = np.mean([cs[j].cr for j in q])
        mean_n = np.mean([cs[j].n_chars for j in q])
        rows.append(csv_row(f"scaling_quartile_{i+1}", 0,
                            f"mean_chars={mean_n:.0f} mean_cr={mean_cr:.2f}x"))
    return rows
