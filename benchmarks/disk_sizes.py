"""Paper §5.3: projected storage for 1M prompts averaging 2KB, per method
(paper: 2GB raw -> ~0.4GB hybrid)."""

from benchmarks.common import METHODS, all_cycles, csv_row


def run() -> list:
    rows = []
    by_method = all_cycles()
    for m in METHODS:
        cs = by_method[m]
        ratio = sum(c.compressed_bytes for c in cs) / sum(c.n_bytes for c in cs)
        projected = 2.0 * ratio  # GB for the paper's 1M x 2KB scenario
        rows.append(csv_row(f"disk_1M_prompts_{m}", 0,
                            f"2.00GB->{projected:.2f}GB"))
    return rows
