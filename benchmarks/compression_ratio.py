"""Paper Table 5 / §5.1: compression ratios by method.
Reference bands: hybrid 4.89x (1.22-19.09), zstd 4.76x (1.22-19.77),
token 1.02x (0.74-2.05 — cl100k vocab; our in-domain 8k BPE tokenizes
tighter so the token band sits higher; the ORDERING claims are what we
validate)."""

from benchmarks.common import METHODS, all_cycles, csv_row, stats


def run() -> list:
    rows = []
    by_method = all_cycles()
    for m in METHODS:
        cs = by_method[m]
        st = stats(c.cr for c in cs)
        us = 1e6 * sum(c.t_compress_s for c in cs) / len(cs)
        rows.append(csv_row(
            f"table5_cr_{m}", us,
            f"mean={st['mean']:.2f}x min={st['min']:.2f}x max={st['max']:.2f}x std={st['std']:.2f}"))
    hyb = stats(c.cr for c in by_method["hybrid"])["mean"]
    zst = stats(c.cr for c in by_method["zstd"])["mean"]
    tok = stats(c.cr for c in by_method["token"])["mean"]
    ok = hyb >= zst and zst > tok
    rows.append(csv_row("table5_ordering_hybrid>=zstd>token", 0,
                        f"{'PASS' if ok else 'FAIL'} ({hyb:.2f}/{zst:.2f}/{tok:.2f})"))
    return rows
