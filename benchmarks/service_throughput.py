"""Service-tier throughput: async ingest vs synchronous `put_many`,
cached vs uncached serve-path admission, dictionary-trained compaction on
a short-prompt corpus, and online shard rebalancing.

Ingest: the same corpus flows into identical sharded stores (a) via
synchronous `put_many` group commits and (b) via the ingest queue —
dispatcher planning overlapped with per-shard writer threads fsyncing in
parallel.  Two async numbers matter: *submit* throughput (what a producer
in the request path observes — no fsync on its critical path) and
*end-to-end* throughput (submit + drain, everything durable).

Admission: repeat `get_tokens_many` rounds over a fixed key set, straight
from the store (codec decode every round) vs through the PromptService
token cache (decode only on round 1).

Dictionary compaction: a corpus of short templated prompts — where
per-record compression is weakest because every record re-learns the
shared structure — is ingested, then compacted with dictionary training
enabled.  The row reports total store bytes before vs after WITH the
sidecars charged; the reduction must be strict (the adoption rule's
guarantee), so the row carries FAIL if it ever is not.

Rebalance: the same store is re-partitioned online across a different
shard count; the row reports wall time and fails if any key is lost.

Skips gracefully (SKIP row, no failure) when the store root is
read-only — set REPRO_BENCH_STORE_ROOT to move it off the default temp
dir.  Writes `benchmarks/BENCH_service_throughput.json` so the perf
trajectory file set tracks the serve path.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path

from benchmarks.common import csv_row

_OUT = Path(__file__).resolve().parent / "BENCH_service_throughput.json"

N_PROMPTS = 256
N_SHARDS = 8
BATCH = 32
REPS = 3           # best-of, sync/async alternating (fsync cost is noisy)
ADMIT_KEYS = 48
ADMIT_ROUNDS = 6
SHORT_N = 192      # dict-compaction corpus: short templated prompts
DICT_SHARDS = 4    # its shard count (fewer shards -> more records/dict);
                   # the rebalance row then re-partitions it to N_SHARDS


def _store_root() -> str:
    return os.environ.get("REPRO_BENCH_STORE_ROOT", tempfile.gettempdir())


def _writable(root: str) -> bool:
    try:
        with tempfile.TemporaryDirectory(dir=root):
            return True
    except OSError:
        return False


def _texts() -> list:
    return [f"user {i}: summarize incident ticket #{i % 17}; "
            f"attach the runbook diff and escalate. " * 4
            for i in range(N_PROMPTS)]


def _short_texts() -> list:
    return [f"q{i}: fetch the weather for city #{i % 31} and reply "
            "tersely with units." for i in range(SHORT_N)]


def run() -> list:
    root = _store_root()
    if not _writable(root):
        # e.g. a read-only container mount: report, don't fail the suite
        return [csv_row("service_throughput", 0,
                        f"SKIP:store_root_read_only:{root}")]

    from repro.core.api import PromptCompressor
    from repro.core.store import ShardedPromptStore
    from repro.service import PromptService
    from repro.service.ingest import IngestQueue
    from repro.tokenizer.vocab import default_tokenizer

    tok = default_tokenizer()
    texts = _texts()
    rows = []

    def _sync_once() -> float:
        with tempfile.TemporaryDirectory(dir=root) as tmp:
            store = ShardedPromptStore(tmp, PromptCompressor(tok, method="token"),
                                       n_shards=N_SHARDS)
            t0 = time.perf_counter()
            for i in range(0, len(texts), BATCH):
                store.put_many(texts[i:i + BATCH])
            dt = time.perf_counter() - t0
            assert len(store) == len(set(texts))
            return dt

    def _async_once() -> tuple:
        with tempfile.TemporaryDirectory(dir=root) as tmp:
            store = ShardedPromptStore(tmp, PromptCompressor(tok, method="token"),
                                       n_shards=N_SHARDS)
            with IngestQueue(store, flush_batch=BATCH,
                             max_pending=4 * BATCH) as q:
                t0 = time.perf_counter()
                tickets = [q.submit(texts[i:i + BATCH])
                           for i in range(0, len(texts), BATCH)]
                t_submit = time.perf_counter() - t0
                q.drain()
                t_e2e = time.perf_counter() - t0
            for t in tickets:
                t.wait(0)
            assert len(store) == len(set(texts))
            return t_submit, t_e2e

    # -- ingest: sync put_many vs async queue, best-of-REPS alternating ------
    _sync_once()  # warm FS + tokenizer word cache
    t_sync = min(_sync_once() for _ in range(REPS))
    async_times = [_async_once() for _ in range(REPS)]
    t_submit = min(t for t, _ in async_times)
    t_async = min(t for _, t in async_times)
    pps_sync = len(texts) / t_sync
    pps_submit = len(texts) / t_submit
    pps_async = len(texts) / t_async

    rows.append(csv_row("service_ingest_sync_put_many",
                        1e6 * t_sync / len(texts), f"{pps_sync:.0f}prompts/s"))
    rows.append(csv_row("service_ingest_async_e2e",
                        1e6 * t_async / len(texts),
                        f"{pps_async:.0f}prompts/s "
                        f"speedup={pps_async / pps_sync:.2f}x"))
    rows.append(csv_row("service_ingest_async_submit",
                        1e6 * t_submit / len(texts),
                        f"{pps_submit:.0f}prompts/s "
                        f"producer_speedup={pps_submit / pps_sync:.2f}x"))

    # -- admission: cached vs uncached get_tokens ----------------------------
    with tempfile.TemporaryDirectory(dir=root) as tmp:
        store = ShardedPromptStore(tmp, PromptCompressor(tok, method="hybrid"),
                                   n_shards=N_SHARDS)
        store.put_many(texts[:ADMIT_KEYS])
        keys = store.keys()
        n_admits = ADMIT_ROUNDS * len(keys)

        t0 = time.perf_counter()
        for _ in range(ADMIT_ROUNDS):
            store.get_tokens_many(keys)
        t_uncached = time.perf_counter() - t0

        service = PromptService(store, cache_bytes=64 << 20, ingest_async=False)
        with service:
            t0 = time.perf_counter()
            for _ in range(ADMIT_ROUNDS):
                service.get_tokens_many(keys)
            t_cached = time.perf_counter() - t0
            hit_rate = service.cache.stats()["hit_rate"]

    rows.append(csv_row("service_admit_uncached",
                        1e6 * t_uncached / n_admits, "per_get_tokens"))
    rows.append(csv_row("service_admit_cached",
                        1e6 * t_cached / n_admits,
                        f"speedup={t_uncached / t_cached:.2f}x "
                        f"hit_rate={hit_rate:.2f}"))

    # -- dictionary-trained compaction on the short-prompt corpus ------------
    from repro.service.compaction import compact_store

    short = _short_texts()
    with tempfile.TemporaryDirectory(dir=root) as tmp:
        store = ShardedPromptStore(tmp, PromptCompressor(tok, method="zstd"),
                                   n_shards=DICT_SHARDS)
        short_keys = store.put_many(short)
        st0 = store.stats()
        bytes_before = st0["file_bytes"] + st0["dict_bytes"]
        t0 = time.perf_counter()
        results = compact_store(store, reselect=True, train_dict=True)
        t_dict = time.perf_counter() - t0
        st1 = store.stats()
        bytes_after = st1["file_bytes"] + st1["dict_bytes"]
        n_dicts = sum(1 for r in results if r.used_dict)
        lossless = store.verify_all()["failure"] == 0
        strict_win = bytes_after < bytes_before
        verdict = ("" if strict_win and lossless else
                   " FAIL:lossless" if not lossless else " FAIL:not_strict_win")
        rows.append(csv_row(
            "service_dict_compaction", 1e6 * t_dict / len(short),
            f"{bytes_before}B->{bytes_after}B "
            f"(dicts={n_dicts}/{store.n_shards}, sidecars={st1['dict_bytes']}B) "
            f"win={bytes_before / bytes_after:.2f}x" + verdict))

        # -- online shard rebalance on the same (dict-bearing) store ---------
        t0 = time.perf_counter()
        reb = store.rebalance(N_SHARDS)
        t_reb = time.perf_counter() - t0
        intact = (store.keys() == short_keys
                  and store.verify_all()["failure"] == 0)
        rows.append(csv_row(
            "service_rebalance", 1e6 * t_reb / len(short),
            f"{reb['n_shards_before']}->{reb['n_shards_after']}shards "
            f"{reb['n_records']}records reencoded={reb['n_reencoded']} "
            f"{t_reb * 1e3:.0f}ms" + ("" if intact else " FAIL:keys_lost")))

    doc = {
        "benchmark": "service_throughput",
        "n_prompts": len(texts),
        "n_shards": N_SHARDS,
        "batch": BATCH,
        "ingest_sync_prompts_per_s": pps_sync,
        "ingest_async_e2e_prompts_per_s": pps_async,
        "ingest_async_submit_prompts_per_s": pps_submit,
        "ingest_async_e2e_speedup": pps_async / pps_sync,
        "ingest_async_submit_speedup": pps_submit / pps_sync,
        "admit_keys": ADMIT_KEYS,
        "admit_rounds": ADMIT_ROUNDS,
        "admit_uncached_us": 1e6 * t_uncached / n_admits,
        "admit_cached_us": 1e6 * t_cached / n_admits,
        "admit_cached_speedup": t_uncached / t_cached,
        "admit_cache_hit_rate": hit_rate,
        "dict_short_prompts": len(short),
        "dict_bytes_before": bytes_before,
        "dict_bytes_after": bytes_after,
        "dict_sidecar_bytes": st1["dict_bytes"],
        "dict_shards_adopted": n_dicts,
        "dict_win": bytes_before / bytes_after,
        "rebalance_from": reb["n_shards_before"],
        "rebalance_to": reb["n_shards_after"],
        "rebalance_records": reb["n_records"],
        "rebalance_wall_s": t_reb,
    }
    try:
        _OUT.write_text(json.dumps(doc, indent=1) + "\n")
    except OSError:
        pass  # benchmarks dir itself read-only: keep the csv rows

    return rows
