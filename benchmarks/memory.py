"""Paper §5.5: tracemalloc peak memory per method (paper: 0.10-0.52 MB
means across 386 prompts)."""

from benchmarks.common import METHODS, all_cycles, csv_row, stats


def run() -> list:
    rows = []
    by_method = all_cycles()
    for m in METHODS:
        cs = by_method[m]
        mc = stats(c.mem_compress_mb for c in cs)
        md = stats(c.mem_decompress_mb for c in cs)
        rows.append(csv_row(
            f"mem_{m}", 0,
            f"compress_mean={mc['mean']:.2f}MB max={mc['max']:.2f}MB "
            f"decompress_mean={md['mean']:.2f}MB max={md['max']:.2f}MB"))
    return rows
