# Test tiers (markers registered in pytest.ini; see ARCHITECTURE.md):
#   make analyze     static invariant checker (repro.analysis): lock order,
#                    durability, frozen wire formats, kernel hygiene, env
#                    registry, pool re-entrancy.  Waive a false positive with
#                    `# repro-analysis: disable=REPRO00N <reason>` inline;
#                    re-pin a frozen-format hash (only together with its
#                    golden test) via `python -m repro.analysis --repin-frozen`.
#   make quick       analyze + not-slow tests + golden frame-layout pins
#                    (scripts/check.sh)
#   make crash       crash-injection suite alone (fault points in fsync/replace)
#   make test        full tier-1 (slow + concurrency included)
#   make bench       the full benchmark sweep (writes BENCH_*.json)
#   make bench-codec the codec hot-path sweep alone (BENCH_codec_throughput.json)
#   make bench-kernels the device-kernel parity gate + accelerator sweeps
#                    (BENCH_kernel_codec.json; timings SKIP on CPU hosts)
#   make obs-smoke   REPRO_OBS=0 codec overhead guard (scripts/obs_smoke.py)
#   make gateway-smoke spawn a gateway subprocess, drive concurrent socket
#                    clients, assert latency percentiles + SIGTERM drain
#   make chaos       seeded chaos harness x5 seeds: live writer/standby/replica
#                    fleet under fault injection + SIGKILL takeover; asserts
#                    zero acked-write loss, quarantine + degraded reads, and
#                    fault/retry counters in the obs snapshot (scripts/chaos.py)
PY := PYTHONPATH=src python

.PHONY: analyze quick crash test bench bench-codec bench-kernels obs-smoke \
	gateway-smoke chaos

analyze:
	$(PY) -m repro.analysis src --baseline analysis-baseline.json

quick:
	bash scripts/check.sh

crash:
	$(PY) -m pytest -q -m crash

test:
	$(PY) -m pytest -x -q

bench:
	PYTHONPATH=src:. python benchmarks/run.py

bench-codec:
	PYTHONPATH=src:. python benchmarks/codec_throughput.py

bench-kernels:
	PYTHONPATH=src:. python benchmarks/kernel_throughput.py

obs-smoke:
	$(PY) scripts/obs_smoke.py

gateway-smoke:
	$(PY) scripts/gateway_smoke.py

chaos:
	for s in 0 1 2 3 4; do $(PY) scripts/chaos.py --seed $$s || exit 1; done
